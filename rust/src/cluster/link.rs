//! The interconnect model: ring all-reduce cost over a homogeneous link.
//!
//! The model is the standard bandwidth-optimal ring collective (Shi et
//! al., *Performance Modeling and Evaluation of Distributed Deep Learning
//! Frameworks on GPUs*): reducing an `S`-byte tensor across `N` devices
//! takes `2 * (N - 1)` steps (a reduce-scatter pass followed by an
//! all-gather pass), each step moving `S / N` bytes per link, so
//!
//! ```text
//! t = 2 * (N - 1) * (alpha + (S / N) / beta)
//! ```
//!
//! with `alpha` the per-hop latency and `beta` the link bandwidth. The
//! alpha term makes small tensors latency-bound (many small reduces pay
//! for fusion in real stacks), the beta term makes large tensors
//! bandwidth-bound and — crucially for weak scaling — nearly
//! N-independent: `2 * (N - 1) / N -> 2`, which is exactly why hiding the
//! reduce behind backward compute matters more as the pool grows.

/// A homogeneous point-to-point link (ring topology).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LinkModel {
    /// Per-hop latency in microseconds (launch + wire + sync).
    pub latency_us: f64,
    /// Per-link bandwidth in GB/s.
    pub gb_per_s: f64,
}

impl Default for LinkModel {
    /// PCIe 3.0 x16-class interconnect: the fabric of the paper's K40 era.
    fn default() -> Self {
        Self::pcie3()
    }
}

impl LinkModel {
    /// PCIe 3.0 x16: ~12 GB/s effective per direction, ~10 us per hop.
    pub fn pcie3() -> Self {
        Self {
            latency_us: 10.0,
            gb_per_s: 12.0,
        }
    }

    /// NVLink-class fabric: ~60 GB/s per link, ~5 us per hop.
    pub fn nvlink() -> Self {
        Self {
            latency_us: 5.0,
            gb_per_s: 60.0,
        }
    }

    /// Bandwidth floor applied by [`Self::effective_gb_per_s`]: a link
    /// configured at or below zero (or with a non-finite value) behaves
    /// like a ~1 KB/s wire instead of dividing by zero.
    pub const MIN_GB_PER_S: f64 = 1e-6;

    /// The bandwidth the cost model actually uses: `gb_per_s` when it is
    /// a finite positive number, else clamped to [`Self::MIN_GB_PER_S`].
    /// A degenerate link must yield an enormous-but-finite wire time —
    /// never an `inf`/NaN that would poison the executor's event queue
    /// (whose `push` hard-rejects non-finite times).
    pub fn effective_gb_per_s(&self) -> f64 {
        if self.gb_per_s.is_finite() && self.gb_per_s > 0.0 {
            self.gb_per_s
        } else {
            Self::MIN_GB_PER_S
        }
    }

    /// Time for one ring all-reduce of `bytes` across `replicas` devices.
    /// Zero when nothing needs to move (one replica, or an empty tensor).
    /// Always finite, even for a zero-bandwidth link.
    pub fn ring_allreduce_us(&self, bytes: u64, replicas: usize) -> f64 {
        if replicas <= 1 || bytes == 0 {
            return 0.0;
        }
        let steps = (2 * (replicas - 1)) as f64;
        let hop_bytes = bytes as f64 / replicas as f64;
        // GB/s = 1e3 bytes per microsecond
        steps
            * (self.latency_us
                + hop_bytes / (self.effective_gb_per_s() * 1e3))
    }

    /// Time for a staged transfer: `steps` pipeline steps, each paying
    /// this link's latency and moving `hop_bytes`. The generalized form
    /// of [`Self::ring_allreduce_us`] — `staged_us(2 * (n - 1), s / n)`
    /// is bit-identical to `ring_allreduce_us(s, n)` — used to price the
    /// topology-routed collectives, whose step count and hop size depend
    /// on the collective pattern and routed path.
    pub fn staged_us(&self, steps: usize, hop_bytes: f64) -> f64 {
        if steps == 0 || !(hop_bytes > 0.0) {
            return 0.0;
        }
        steps as f64
            * (self.latency_us
                + hop_bytes / (self.effective_gb_per_s() * 1e3))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_replica_or_empty_tensor_is_free() {
        let l = LinkModel::default();
        assert_eq!(l.ring_allreduce_us(1 << 20, 1), 0.0);
        assert_eq!(l.ring_allreduce_us(0, 8), 0.0);
    }

    #[test]
    fn two_replica_cost_is_latency_plus_wire() {
        let l = LinkModel {
            latency_us: 10.0,
            gb_per_s: 12.0,
        };
        // N=2: 2 steps of S/2 bytes -> total wire bytes = S
        let s = 24_000_000u64; // 24 MB
        let t = l.ring_allreduce_us(s, 2);
        let expect = 2.0 * (10.0 + 12_000_000.0 / 12_000.0);
        assert!((t - expect).abs() < 1e-9, "{t} vs {expect}");
    }

    #[test]
    fn bandwidth_term_saturates_with_replicas() {
        // large tensors: per-device wire time approaches 2 * S / beta as N
        // grows, so doubling the pool barely changes the reduce time —
        // weak scaling is decided by overlap, not by the collective.
        let l = LinkModel::pcie3();
        let s = 256 << 20; // 256 MB: firmly bandwidth-bound
        let t2 = l.ring_allreduce_us(s, 2);
        let t8 = l.ring_allreduce_us(s, 8);
        assert!(t8 > t2, "more steps still cost more");
        assert!(t8 < t2 * 2.0, "but far from linearly: {t2} -> {t8}");
    }

    #[test]
    fn latency_bound_small_tensors_scale_with_steps() {
        let l = LinkModel::pcie3();
        let t2 = l.ring_allreduce_us(64, 2); // 2 steps
        let t4 = l.ring_allreduce_us(64, 4); // 6 steps
        assert!(t4 > t2 * 2.5, "{t2} -> {t4}");
    }

    #[test]
    fn zero_bandwidth_link_stays_finite() {
        // A misconfigured (or deliberately adversarial) link must not be
        // able to mint a non-finite duration: the executor's event queue
        // rejects those with a hard panic.
        for gb in [0.0, -3.0, f64::NAN, f64::INFINITY] {
            let l = LinkModel {
                latency_us: 10.0,
                gb_per_s: gb,
            };
            let t = l.ring_allreduce_us(1 << 20, 2);
            assert!(t.is_finite(), "gb_per_s={gb} gave {t}");
            assert!(t > 0.0, "gb_per_s={gb} gave {t}");
        }
    }

    #[test]
    fn positive_bandwidth_is_passed_through_unclamped() {
        // the clamp must be invisible for every valid configuration
        let l = LinkModel::pcie3();
        assert_eq!(l.effective_gb_per_s(), 12.0);
        assert_eq!(LinkModel::nvlink().effective_gb_per_s(), 60.0);
        assert_eq!(
            l.ring_allreduce_us(24_000_000, 2),
            2.0 * (10.0 + 12_000_000.0 / 12_000.0)
        );
    }

    #[test]
    fn presets_are_ordered() {
        let s = 64 << 20;
        assert!(
            LinkModel::nvlink().ring_allreduce_us(s, 4)
                < LinkModel::pcie3().ring_allreduce_us(s, 4)
        );
    }

    #[test]
    fn staged_form_is_bit_identical_to_the_ring_formula() {
        // the topology collectives are priced through staged_us; the
        // ring-degenerate equivalence guarantee relies on the two forms
        // agreeing to the last bit, not just approximately.
        for l in [LinkModel::pcie3(), LinkModel::nvlink()] {
            for n in [2usize, 3, 4, 8, 16] {
                for bytes in [1u64, 4096, 24_000_000, 256 << 20] {
                    let ring = l.ring_allreduce_us(bytes, n);
                    let staged =
                        l.staged_us(2 * (n - 1), bytes as f64 / n as f64);
                    assert_eq!(ring.to_bits(), staged.to_bits());
                }
            }
        }
    }

    #[test]
    fn degenerate_staged_transfers_are_free_and_finite() {
        let l = LinkModel::pcie3();
        assert_eq!(l.staged_us(0, 1e6), 0.0);
        assert_eq!(l.staged_us(4, 0.0), 0.0);
        assert_eq!(l.staged_us(4, -1.0), 0.0);
        assert_eq!(l.staged_us(4, f64::NAN), 0.0);
        let bad = LinkModel {
            latency_us: 1.0,
            gb_per_s: 0.0,
        };
        assert!(bad.staged_us(2, 1e6).is_finite());
    }
}
