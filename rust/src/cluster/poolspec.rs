//! [`PoolSpec`]: the per-device specification list of a (possibly
//! heterogeneous) device pool.
//!
//! The original API threaded a single [`DeviceSpec`] everywhere, which
//! bakes in the assumption that every replica is the same GPU. The
//! planner family (HEFT/PEFT/lookahead) exists precisely because that
//! assumption fails: per-algorithm costs shift across GPU generations
//! (Chetlur et al.), so on a mixed K40/P100/V100/A100 pool placement and
//! ordering genuinely matter. `PoolSpec` is the list of member specs,
//! ordered by device id; every layer that used to take one spec —
//! `Planner`, `Session`, `DevicePool`, the executors — now resolves the
//! spec *per device* through it. A one-member pool reproduces the old
//! homogeneous behavior bit-for-bit.

use std::fmt;

use crate::gpusim::{DeviceSpec, UnknownDevice};

/// Per-device specifications of a device pool, ordered by device id.
#[derive(Clone, Debug, PartialEq)]
pub struct PoolSpec {
    members: Vec<DeviceSpec>,
}

impl PoolSpec {
    /// A pool of explicit member specs (device `i` runs `members[i]`).
    pub fn new(members: Vec<DeviceSpec>) -> Self {
        assert!(!members.is_empty(), "a pool needs at least one device");
        Self { members }
    }

    /// The degenerate single-device pool (the legacy homogeneous API).
    pub fn single(spec: DeviceSpec) -> Self {
        Self::new(vec![spec])
    }

    /// `n` identical devices.
    pub fn homogeneous(spec: DeviceSpec, n: usize) -> Self {
        assert!(n >= 1, "a pool needs at least one device");
        Self::new(vec![spec; n])
    }

    /// Parse a device list like `"k40,v100x2,a100"`: comma-separated
    /// preset names, each with an optional `xN` multiplicity suffix.
    /// Unknown names are refused with the preset-listing
    /// [`UnknownDevice`] error; a single name degenerates to the
    /// homogeneous behavior of the old `--device` flag.
    pub fn parse(list: &str) -> Result<Self, UnknownDevice> {
        let mut members = Vec::new();
        for part in list.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            // split an optional trailing xN multiplicity off the name
            let (name, count) = match part.rsplit_once(['x', 'X']) {
                Some((name, n)) if !name.is_empty() => {
                    match n.parse::<usize>() {
                        Ok(c) if c >= 1 => (name, c),
                        _ => (part, 1),
                    }
                }
                _ => (part, 1),
            };
            let spec = DeviceSpec::preset(name)?;
            for _ in 0..count {
                members.push(spec.clone());
            }
        }
        if members.is_empty() {
            return Err(UnknownDevice {
                name: list.to_string(),
            });
        }
        Ok(Self { members })
    }

    /// Number of devices in the pool.
    #[allow(clippy::len_without_is_empty)] // never empty by construction
    pub fn len(&self) -> usize {
        self.members.len()
    }

    /// The spec of device `d`.
    pub fn device(&self, d: usize) -> &DeviceSpec {
        &self.members[d]
    }

    /// All member specs, ordered by device id.
    pub fn members(&self) -> &[DeviceSpec] {
        &self.members
    }

    /// Display names of the members, ordered by device id.
    pub fn names(&self) -> Vec<String> {
        self.members.iter().map(|m| m.name.clone()).collect()
    }

    /// Whether every member is the same spec (placement cannot matter).
    pub fn is_homogeneous(&self) -> bool {
        self.members.iter().all(|m| *m == self.members[0])
    }
}

impl fmt::Display for PoolSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.names().join(" + "))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_expands_multiplicity_suffixes() {
        let p = PoolSpec::parse("k40,v100x2,a100").unwrap();
        assert_eq!(p.len(), 4);
        assert_eq!(p.device(0).name, "Tesla K40");
        assert_eq!(p.device(1).name, "Tesla V100");
        assert_eq!(p.device(2).name, "Tesla V100");
        assert_eq!(p.device(3).name, "NVIDIA A100");
        assert!(!p.is_homogeneous());
    }

    #[test]
    fn single_name_degenerates_to_homogeneous() {
        let p = PoolSpec::parse("v100").unwrap();
        assert_eq!(p.len(), 1);
        assert!(p.is_homogeneous());
        assert_eq!(p, PoolSpec::single(crate::gpusim::DeviceSpec::v100()));
        // "v100x4" is homogeneous too, just wider
        let p4 = PoolSpec::parse("v100x4").unwrap();
        assert_eq!(p4.len(), 4);
        assert!(p4.is_homogeneous());
    }

    #[test]
    fn unknown_names_error_listing_presets() {
        let err = PoolSpec::parse("k40,h100").unwrap_err();
        assert_eq!(err.name, "h100");
        let msg = err.to_string();
        for preset in crate::gpusim::DeviceSpec::PRESET_NAMES {
            assert!(msg.contains(preset), "{msg} lacks {preset}");
        }
        // an empty list is refused, not an empty pool
        assert!(PoolSpec::parse("").is_err());
        assert!(PoolSpec::parse(" , ,").is_err());
    }

    #[test]
    fn parse_is_case_insensitive_and_trims() {
        let p = PoolSpec::parse(" K40 , V100X2 ").unwrap();
        assert_eq!(p.len(), 3);
        assert_eq!(p.device(2).name, "Tesla V100");
    }
}
