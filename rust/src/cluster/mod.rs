//! Multi-GPU data-parallel execution: a pool of per-device simulated
//! engines plus a modeled interconnect.
//!
//! The paper's argument — serial launch leaves inter-op parallelism on
//! the table — extends across devices: in data-parallel training the
//! gradient all-reduce is the serial tail, and the same event-driven
//! machinery that overlaps independent layers on one GPU can overlap
//! each parameter's reduction with the remainder of the backward pass
//! (Shi et al., *Performance Modeling and Evaluation of Distributed Deep
//! Learning Frameworks on GPUs*). The pieces:
//!
//! - [`LinkModel`] — ring all-reduce cost over a homogeneous link
//!   (`2 (N-1)` hops of `S / N` bytes: latency- or bandwidth-bound).
//! - [`data_parallel_dag`] — N device-tagged copies of the training DAG
//!   plus one [`crate::graph::OpKind::GradReduce`] node per parameter,
//!   depending on the N copies of that parameter's gradient producer
//!   (or, in serial-tail mode, on every replica's full backward pass).
//! - [`PoolSpec`] — per-device specs of a (possibly heterogeneous)
//!   pool, ordered by device id; the planner family resolves costs and
//!   placement per member through it.
//! - [`DevicePool`] — the facade: plans the replicated DAG through the
//!   replica-aware [`crate::plan::Planner`] (schema v5: per-node device
//!   assignments plus the per-device spec-name pool) and executes it on
//!   the multi-device event executor,
//!   which instantiates one `gpusim::Engine` per device plus a single
//!   interconnect lane that serializes collectives, NCCL-style.
//!
//! Single-GPU runs never enter this module's code paths: a one-replica
//! pool degenerates to `Session::run` on the plain training DAG, pinned
//! bit-identical by `rust/tests/cluster_scaling.rs`.

mod link;
mod pool;
mod poolspec;
mod topology;

pub use link::LinkModel;
pub use pool::{
    data_parallel_dag, hierarchical_reduce_dag, pipeline_parallel_dag,
    reduce_sites, ClusterConfig, DevicePool, PoolOptions, ReduceSite,
};
pub use poolspec::PoolSpec;
pub use topology::{Link, LinkKind, Strategy, Topology, TopologySpec};
