//! Layer-3 coordinator: the paper's system contribution.
//!
//! - [`selector`] — convolution-algorithm selection policies, from
//!   TensorFlow's fastest-only autotuning to the paper's proposed
//!   profile-guided multi-metric selection, including the k-wide
//!   [`selector::select_group`] packing.
//! - [`scheduler`] — the scheduler vocabulary ([`ScheduleConfig`],
//!   [`ScheduleResult`], priorities, the non-conv duration model).
//!   Planning itself lives in [`crate::plan::Planner`]; replay in
//!   [`crate::plan::Plan`]; the serving facade is
//!   [`crate::plan::Session`].
//! - [`pairing`] — discovery of complementary convolution pairs and
//!   k-wide groups (the paper's "27 similar cases" analysis).

pub mod pairing;
pub mod scheduler;
pub mod selector;

pub use pairing::{discover_groups, discover_pairs, GroupFinding, PairFinding};
pub use scheduler::{
    non_conv_time_us, OpExec, PriorityPolicy, ScheduleConfig,
    ScheduleResult,
};
pub use selector::{
    estimate_group_makespan_us, estimate_pair_makespan_us, select_group,
    select_pair, select_solo, selector_invocations, GroupSelection,
    SelectionPolicy,
};
