//! Layer-3 coordinator: the paper's system contribution.
//!
//! - [`selector`] — convolution-algorithm selection policies, from
//!   TensorFlow's fastest-only autotuning to the paper's proposed
//!   profile-guided multi-metric selection.
//! - [`scheduler`] — ready-queue DAG execution over the GPU simulator with
//!   workspace-aware admission.
//! - [`pairing`] — discovery of complementary convolution pairs (the
//!   paper's "27 similar cases" analysis).

pub mod pairing;
pub mod scheduler;
pub mod selector;

pub use pairing::{discover_pairs, PairFinding};
pub use scheduler::{
    non_conv_time_us, Coordinator, OpExec, ScheduleConfig, ScheduleResult,
};
pub use selector::{
    estimate_pair_makespan_us, select_pair, select_solo, SelectionPolicy,
};
