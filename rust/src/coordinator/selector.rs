//! Convolution-algorithm selection policies.
//!
//! `FastestOnly` reproduces TensorFlow r1.10's autotuner (paper §2.1: "in
//! the first iteration, TensorFlow tests all algorithms for each
//! convolution and chooses the fastest one"). `ProfileGuided` is the
//! paper's proposal: a multi-metric selection that considers SM resource
//! complementarity and workspace, enabling concurrent execution.

use crate::convlib::{kernel_desc, ConvParams, KernelDesc, ALL_ALGORITHMS};
use crate::gpusim::partition::plan_intra_sm;
use crate::gpusim::{isolated_time_us, natural_residency, DeviceSpec};

/// Algorithm-selection policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SelectionPolicy {
    /// TensorFlow r1.10: fastest algorithm, ignoring resources/workspace.
    FastestOnly,
    /// Smallest workspace, ties broken by speed (memory-constrained mode).
    MemoryMin,
    /// Scalarized time-memory trade-off.
    Balanced,
    /// The paper's proposal: complementarity-aware selection for
    /// concurrent execution (falls back to Balanced for solo ops).
    ProfileGuided,
}

impl SelectionPolicy {
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "fastest" | "fastest_only" | "tensorflow" => Some(Self::FastestOnly),
            "memory" | "memory_min" => Some(Self::MemoryMin),
            "balanced" => Some(Self::Balanced),
            "profile" | "profile_guided" => Some(Self::ProfileGuided),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::FastestOnly => "fastest_only",
            Self::MemoryMin => "memory_min",
            Self::Balanced => "balanced",
            Self::ProfileGuided => "profile_guided",
        }
    }
}

/// All candidate descriptors whose workspace fits the budget.
fn candidates(
    p: &ConvParams,
    dev: &DeviceSpec,
    ws_budget: u64,
) -> Vec<KernelDesc> {
    ALL_ALGORITHMS
        .iter()
        .filter_map(|&a| kernel_desc(a, p, dev))
        .filter(|d| d.workspace_bytes <= ws_budget)
        .collect()
}

/// Select an algorithm for a convolution executing alone.
///
/// Returns `None` only if no algorithm fits the workspace budget (the
/// coordinator then treats this as an OOM scheduling failure).
pub fn select_solo(
    policy: SelectionPolicy,
    p: &ConvParams,
    dev: &DeviceSpec,
    ws_budget: u64,
) -> Option<KernelDesc> {
    let mut cands = candidates(p, dev, ws_budget);
    if cands.is_empty() {
        return None;
    }
    match policy {
        SelectionPolicy::FastestOnly => {
            cands.sort_by(|a, b| {
                isolated_time_us(a, dev)
                    .partial_cmp(&isolated_time_us(b, dev))
                    .unwrap()
            });
        }
        SelectionPolicy::MemoryMin => {
            cands.sort_by(|a, b| {
                a.workspace_bytes.cmp(&b.workspace_bytes).then(
                    isolated_time_us(a, dev)
                        .partial_cmp(&isolated_time_us(b, dev))
                        .unwrap(),
                )
            });
        }
        SelectionPolicy::Balanced | SelectionPolicy::ProfileGuided => {
            // time x (1 + ws/budget): a 2x-memory algorithm must be
            // correspondingly faster to win.
            cands.sort_by(|a, b| {
                let score = |d: &KernelDesc| {
                    isolated_time_us(d, dev)
                        * (1.0
                            + d.workspace_bytes as f64
                                / ws_budget.max(1) as f64)
                };
                score(a).partial_cmp(&score(b)).unwrap()
            });
        }
    }
    cands.into_iter().next()
}

/// Analytic co-run estimate for a kernel pair under intra-SM quotas:
/// two-phase fluid model (both run at planned rates; when the first
/// finishes, the survivor continues at full rate).
pub fn estimate_pair_makespan_us(
    a: &KernelDesc,
    b: &KernelDesc,
    dev: &DeviceSpec,
) -> f64 {
    let t_a = isolated_time_us(a, dev);
    let t_b = isolated_time_us(b, dev);
    let plan = plan_intra_sm(
        &[&a.launch, &b.launch],
        &[a.alu_util, b.alu_util],
        dev,
    );
    let rn_a = natural_residency(&a.launch, dev).max(1) as f64;
    let rn_b = natural_residency(&b.launch, dev).max(1) as f64;
    let f_a = plan[0] as f64 / rn_a;
    let f_b = plan[1] as f64 / rn_b;
    if f_a <= 0.0 || f_b <= 0.0 {
        return t_a + t_b; // no co-residency: serial
    }
    let demand = a.alu_util * f_a + b.alu_util * f_b;
    let phi = if demand > 1.0 { 1.0 / demand } else { 1.0 };
    // progress rates relative to isolated execution
    let v_a = phi * f_a;
    let v_b = phi * f_b;
    // phase 1: until the shorter (in stretched time) kernel completes
    let end_a = t_a / v_a;
    let end_b = t_b / v_b;
    if end_a <= end_b {
        // b has done end_a * v_b worth of its t_b
        let b_left = t_b - end_a * v_b;
        end_a + b_left
    } else {
        let a_left = t_a - end_b * v_a;
        end_b + a_left
    }
}

/// The paper's concurrent selection: pick algorithms for two independent
/// convolutions that minimize the estimated co-run makespan, subject to
/// combined workspace fitting the budget. Returns the pair of descriptors
/// and the estimate.
pub fn select_pair(
    pa: &ConvParams,
    pb: &ConvParams,
    dev: &DeviceSpec,
    ws_budget: u64,
) -> Option<(KernelDesc, KernelDesc, f64)> {
    let cas = candidates(pa, dev, ws_budget);
    let cbs = candidates(pb, dev, ws_budget);
    let mut best: Option<(KernelDesc, KernelDesc, f64)> = None;
    for a in &cas {
        for b in &cbs {
            if a.workspace_bytes + b.workspace_bytes > ws_budget {
                continue;
            }
            let est = estimate_pair_makespan_us(a, b, dev);
            if best.as_ref().map_or(true, |(_, _, t)| est < *t) {
                best = Some((a.clone(), b.clone(), est));
            }
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::convlib::Algorithm;

    fn k40() -> DeviceSpec {
        DeviceSpec::k40()
    }

    const GB4: u64 = 4 * 1024 * 1024 * 1024;

    #[test]
    fn fastest_only_picks_fft_on_table2_conv() {
        // Paper: TensorFlow selects FFT (36 ms) for the Table 2 conv.
        let d = select_solo(
            SelectionPolicy::FastestOnly,
            &ConvParams::table2_5x5(),
            &k40(),
            u64::MAX,
        )
        .unwrap();
        assert_eq!(d.algo, Algorithm::Fft);
    }

    #[test]
    fn memory_min_picks_gemm_on_table2_conv() {
        let d = select_solo(
            SelectionPolicy::MemoryMin,
            &ConvParams::table2_5x5(),
            &k40(),
            u64::MAX,
        )
        .unwrap();
        assert_eq!(d.algo, Algorithm::Gemm); // 0 workspace
    }

    #[test]
    fn fastest_respects_budget() {
        // With a 1 GB budget the 2.2 GB FFT is inadmissible; the picked
        // algorithm must fit and be fastest among the fitting set.
        let budget = 1024 * 1024 * 1024;
        let d = select_solo(
            SelectionPolicy::FastestOnly,
            &ConvParams::table2_5x5(),
            &k40(),
            budget,
        )
        .unwrap();
        assert!(d.workspace_bytes <= budget);
        assert_eq!(d.algo, Algorithm::WinogradNonfused); // 691 MB, 46 ms
    }

    #[test]
    fn balanced_trades_time_for_memory() {
        let p = ConvParams::table2_5x5();
        let d = select_solo(SelectionPolicy::Balanced, &p, &k40(), GB4)
            .unwrap();
        // with memory in the objective, the 2.2GB FFT loses to a leaner
        // algorithm
        assert_ne!(d.algo, Algorithm::Fft);
    }

    #[test]
    fn pair_selection_finds_complementary_algos() {
        // The Table-1 scenario: the two independent inception-3a convs.
        // Profile-guided pairing must find an assignment whose estimated
        // makespan beats the best serial assignment.
        let dev = k40();
        let pa = ConvParams::incep3a_3x3(32);
        let pb = ConvParams::incep3a_5x5(32);
        let (da, db, paired) =
            select_pair(&pa, &pb, &dev, GB4).unwrap();
        let best_serial = {
            let fa = select_solo(SelectionPolicy::FastestOnly, &pa, &dev, GB4)
                .unwrap();
            let fb = select_solo(SelectionPolicy::FastestOnly, &pb, &dev, GB4)
                .unwrap();
            isolated_time_us(&fa, &dev) + isolated_time_us(&fb, &dev)
        };
        assert!(
            paired < best_serial,
            "paired {paired} vs serial {best_serial} ({} + {})",
            da.algo,
            db.algo
        );
        assert_ne!((da.algo, db.algo), (Algorithm::ImplicitPrecompGemm,
                                        Algorithm::ImplicitPrecompGemm),
                   "pairing should avoid TF's both-PRECOMP choice");
    }

    #[test]
    fn pair_estimate_bounds() {
        // paired estimate never beats max(t_a, t_b) nor exceeds t_a + t_b
        let dev = k40();
        let p = ConvParams::incep3a_3x3(32);
        let a = kernel_desc(Algorithm::ImplicitPrecompGemm, &p, &dev).unwrap();
        let b = kernel_desc(Algorithm::FftTiling, &p, &dev).unwrap();
        let est = estimate_pair_makespan_us(&a, &b, &dev);
        let ta = isolated_time_us(&a, &dev);
        let tb = isolated_time_us(&b, &dev);
        assert!(est <= ta + tb + 1e-6);
        assert!(est >= ta.max(tb) - 1e-6);
    }

    #[test]
    fn impossible_budget_returns_none() {
        // even GEMM (0 ws) fits any budget, so use budget 0 and an op where
        // all algorithms need workspace... GEMM always fits: so None never
        // happens for convs. Verify the always-Some contract instead.
        let d = select_solo(
            SelectionPolicy::FastestOnly,
            &ConvParams::incep3a_3x3(32),
            &k40(),
            0,
        );
        assert!(d.is_some()); // GEMM/DIRECT are workspace-free fallbacks
    }

    #[test]
    fn policy_parsing() {
        assert_eq!(
            SelectionPolicy::parse("tensorflow"),
            Some(SelectionPolicy::FastestOnly)
        );
        assert_eq!(
            SelectionPolicy::parse("profile_guided"),
            Some(SelectionPolicy::ProfileGuided)
        );
        assert_eq!(SelectionPolicy::parse("?"), None);
    }
}
