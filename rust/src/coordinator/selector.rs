//! Convolution-algorithm selection policies.
//!
//! `FastestOnly` reproduces TensorFlow r1.10's autotuner (paper §2.1: "in
//! the first iteration, TensorFlow tests all algorithms for each
//! convolution and chooses the fastest one"). `ProfileGuided` is the
//! paper's proposal: a multi-metric selection that considers SM resource
//! complementarity and workspace, enabling concurrent execution.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::convlib::{
    kernel_desc, ConvParams, KernelDesc, ALL_ALGORITHMS,
};
use crate::gpusim::partition::plan_intra_sm;
use crate::gpusim::{isolated_time_us, natural_residency, DeviceSpec};

/// Process-wide count of selector entry-point invocations ([`select_solo`],
/// [`select_pair`], [`select_group`]). This is the plan/execute split's
/// observable contract: building a `plan::Plan` spends selector calls,
/// replaying one spends none — `rust/tests/session_cache.rs` pins a
/// `Session` cache hit to a zero delta on this counter.
static SELECTOR_INVOCATIONS: AtomicU64 = AtomicU64::new(0);

/// Monotonic count of selector invocations in this process. Read a delta
/// around a region to measure how much selection work it performed.
pub fn selector_invocations() -> u64 {
    SELECTOR_INVOCATIONS.load(Ordering::Relaxed)
}

fn count_invocation() {
    SELECTOR_INVOCATIONS.fetch_add(1, Ordering::Relaxed);
}

/// Algorithm-selection policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SelectionPolicy {
    /// TensorFlow r1.10: fastest algorithm, ignoring resources/workspace.
    FastestOnly,
    /// Smallest workspace, ties broken by speed (memory-constrained mode).
    MemoryMin,
    /// Scalarized time-memory trade-off.
    Balanced,
    /// The paper's proposal: complementarity-aware selection for
    /// concurrent execution (falls back to Balanced for solo ops).
    ProfileGuided,
}

impl SelectionPolicy {
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "fastest" | "fastest_only" | "tensorflow" => Some(Self::FastestOnly),
            "memory" | "memory_min" => Some(Self::MemoryMin),
            "balanced" => Some(Self::Balanced),
            "profile" | "profile_guided" => Some(Self::ProfileGuided),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::FastestOnly => "fastest_only",
            Self::MemoryMin => "memory_min",
            Self::Balanced => "balanced",
            Self::ProfileGuided => "profile_guided",
        }
    }
}

/// All candidate descriptors whose workspace fits the budget.
fn candidates_for(
    p: &ConvParams,
    dev: &DeviceSpec,
    ws_budget: u64,
) -> Vec<KernelDesc> {
    ALL_ALGORITHMS
        .iter()
        .filter_map(|&a| kernel_desc(a, p, dev))
        .filter(|d| d.workspace_bytes <= ws_budget)
        .collect()
}

/// Select an algorithm for a convolution executing alone.
///
/// Returns `None` only if no algorithm fits the workspace budget (the
/// coordinator then treats this as an OOM scheduling failure).
pub fn select_solo(
    policy: SelectionPolicy,
    p: &ConvParams,
    dev: &DeviceSpec,
    ws_budget: u64,
) -> Option<KernelDesc> {
    count_invocation();
    let mut cands = candidates_for(p, dev, ws_budget);
    if cands.is_empty() {
        return None;
    }
    match policy {
        SelectionPolicy::FastestOnly => {
            cands.sort_by(|a, b| {
                isolated_time_us(a, dev)
                    .partial_cmp(&isolated_time_us(b, dev))
                    .unwrap()
            });
        }
        SelectionPolicy::MemoryMin => {
            cands.sort_by(|a, b| {
                a.workspace_bytes.cmp(&b.workspace_bytes).then(
                    isolated_time_us(a, dev)
                        .partial_cmp(&isolated_time_us(b, dev))
                        .unwrap(),
                )
            });
        }
        SelectionPolicy::Balanced | SelectionPolicy::ProfileGuided => {
            // time x (1 + ws/budget): a 2x-memory algorithm must be
            // correspondingly faster to win.
            cands.sort_by(|a, b| {
                let score = |d: &KernelDesc| {
                    isolated_time_us(d, dev)
                        * (1.0
                            + d.workspace_bytes as f64
                                / ws_budget.max(1) as f64)
                };
                score(a).partial_cmp(&score(b)).unwrap()
            });
        }
    }
    cands.into_iter().next()
}

/// Analytic co-run estimate for a kernel pair under intra-SM quotas:
/// two-phase fluid model (both run at planned rates; when the first
/// finishes, the survivor continues at full rate).
pub fn estimate_pair_makespan_us(
    a: &KernelDesc,
    b: &KernelDesc,
    dev: &DeviceSpec,
) -> f64 {
    let t_a = isolated_time_us(a, dev);
    let t_b = isolated_time_us(b, dev);
    let plan = plan_intra_sm(
        &[&a.launch, &b.launch],
        &[a.alu_util, b.alu_util],
        dev,
    );
    let rn_a = natural_residency(&a.launch, dev).max(1) as f64;
    let rn_b = natural_residency(&b.launch, dev).max(1) as f64;
    let f_a = plan[0] as f64 / rn_a;
    let f_b = plan[1] as f64 / rn_b;
    if f_a <= 0.0 || f_b <= 0.0 {
        return t_a + t_b; // no co-residency: serial
    }
    let demand = a.alu_util * f_a + b.alu_util * f_b;
    let phi = if demand > 1.0 { 1.0 / demand } else { 1.0 };
    // progress rates relative to isolated execution
    let v_a = phi * f_a;
    let v_b = phi * f_b;
    // phase 1: until the shorter (in stretched time) kernel completes
    let end_a = t_a / v_a;
    let end_b = t_b / v_b;
    if end_a <= end_b {
        // b has done end_a * v_b worth of its t_b
        let b_left = t_b - end_a * v_b;
        end_a + b_left
    } else {
        let a_left = t_a - end_b * v_a;
        end_b + a_left
    }
}

/// Analytic co-run estimate for a k-kernel group under intra-SM quotas:
/// a multi-phase fluid model. Each phase runs every unfinished member at
/// the rate its residency quota allows (issue capacity shared when
/// oversubscribed); when a member finishes, quotas are re-planned for the
/// survivors and the next phase begins. For two kernels this reduces
/// exactly to [`estimate_pair_makespan_us`]; members whose blocks cannot
/// co-reside simply serialize after the others.
///
/// This is [`crate::sim::fluid::fluid_makespan`] evaluated at full
/// remaining work — ONE phase-loop implementation shared with the event
/// executor's mid-flight join gate, so the planner's 2% admission margin
/// and the executor's join margin can never drift apart (they price
/// groups through the same function; a second copy of the math is how
/// they would diverge).
pub fn estimate_group_makespan_us(
    descs: &[&KernelDesc],
    dev: &DeviceSpec,
) -> f64 {
    let left: Vec<f64> =
        descs.iter().map(|d| isolated_time_us(d, dev)).collect();
    crate::sim::fluid::fluid_makespan(descs, &left, dev)
}

/// One k-wide co-execution selection: which ready candidates to co-run
/// and with which algorithms.
#[derive(Clone, Debug)]
pub struct GroupSelection {
    /// Indices into the candidate slice, in admission order (seed first).
    pub members: Vec<usize>,
    /// Chosen kernel descriptor per member (parallel to `members`).
    pub descs: Vec<KernelDesc>,
    /// Fluid-model estimate of the group's co-run makespan.
    pub est_us: f64,
    /// Fastest-solo serial baseline over the same members.
    pub serial_us: f64,
}

impl GroupSelection {
    pub fn combined_workspace(&self) -> u64 {
        self.descs.iter().map(|d| d.workspace_bytes).sum()
    }

    pub fn speedup(&self) -> f64 {
        if self.est_us <= 0.0 {
            1.0
        } else {
            self.serial_us / self.est_us
        }
    }
}

/// Admission margin: a candidate joins a group only when the estimated
/// group makespan beats serializing it after the group by at least this
/// factor (guards against estimate noise turning into regressions).
const GROUP_GAIN_MARGIN: f64 = 0.98;

/// k-wide generalization of [`select_pair`]: greedily pack up to `k` of
/// the `candidates` (which the caller passes in priority order; index 0
/// seeds the group) under the joint SM-resource and workspace budget.
///
/// The first extension performs the exact legacy pair search — a joint
/// scan over both members' algorithm spaces for every possible partner —
/// so `k = 2` reproduces `select_pair`'s choices. Later extensions keep
/// admitted algorithms fixed and search only the newcomer's algorithms
/// against the multi-phase fluid estimate. Every admission must beat the
/// serial alternative by [`GROUP_GAIN_MARGIN`], so a group's estimate is
/// always at most the sum of its members' fastest-solo times.
pub fn select_group(
    candidates: &[&ConvParams],
    k: usize,
    dev: &DeviceSpec,
    ws_budget: u64,
) -> Option<GroupSelection> {
    count_invocation();
    if candidates.is_empty() || k == 0 {
        return None;
    }
    // Fastest-solo descriptor and time per candidate, computed once: the
    // extension loop below would otherwise re-sort every non-member's
    // algorithm space on every iteration.
    let solos: Vec<Option<(KernelDesc, f64)>> = candidates
        .iter()
        .map(|p| {
            select_solo(SelectionPolicy::FastestOnly, p, dev, ws_budget)
                .map(|d| {
                    let t = isolated_time_us(&d, dev);
                    (d, t)
                })
        })
        .collect();
    let (seed_desc, seed_t) = solos[0].clone()?;
    let mut members = vec![0usize];
    let mut descs = vec![seed_desc];
    let mut est = seed_t;
    let mut serial = seed_t;
    if k >= 2 && candidates.len() >= 2 {
        // First extension: joint (seed, partner) algorithm search over
        // every other candidate — exactly the legacy pair exploration.
        let mut best: Option<(usize, KernelDesc, KernelDesc, f64, f64)> =
            None;
        for (j, cand) in candidates.iter().enumerate().skip(1) {
            let Some(&(_, tj)) = solos[j].as_ref() else { continue };
            let Some((da, db, e)) =
                select_pair(candidates[0], cand, dev, ws_budget)
            else {
                continue;
            };
            if e >= (seed_t + tj) * GROUP_GAIN_MARGIN {
                continue;
            }
            let saving = (seed_t + tj) - e;
            let beats = best
                .as_ref()
                .map_or(true, |&(_, _, _, be, bt)| {
                    saving > (seed_t + bt) - be
                });
            if beats {
                best = Some((j, da, db, e, tj));
            }
        }
        if let Some((j, da, db, e, tj)) = best {
            members = vec![0, j];
            descs = vec![da, db];
            est = e;
            serial = seed_t + tj;
        }
    }
    while members.len() >= 2 && members.len() < k {
        let held: u64 = descs.iter().map(|d| d.workspace_bytes).sum();
        let budget_left = ws_budget.saturating_sub(held);
        let mut best_add: Option<(usize, KernelDesc, f64, f64)> = None;
        for (j, cand) in candidates.iter().enumerate() {
            if members.contains(&j) {
                continue;
            }
            let Some(&(_, tj)) = solos[j].as_ref() else { continue };
            for dj in candidates_for(cand, dev, budget_left) {
                let mut group: Vec<&KernelDesc> = descs.iter().collect();
                group.push(&dj);
                let e2 = estimate_group_makespan_us(&group, dev);
                if e2 >= (est + tj) * GROUP_GAIN_MARGIN {
                    continue;
                }
                let saving = (est + tj) - e2;
                let beats =
                    best_add.as_ref().map_or(true, |&(_, _, pe, pt)| {
                        saving > (est + pt) - pe
                    });
                if beats {
                    best_add = Some((j, dj.clone(), e2, tj));
                }
            }
        }
        match best_add {
            Some((j, dj, e2, tj)) => {
                members.push(j);
                descs.push(dj);
                est = e2;
                serial += tj;
            }
            None => break,
        }
    }
    Some(GroupSelection {
        members,
        descs,
        est_us: est,
        serial_us: serial,
    })
}

/// The paper's concurrent selection: pick algorithms for two independent
/// convolutions that minimize the estimated co-run makespan, subject to
/// combined workspace fitting the budget. Returns the pair of descriptors
/// and the estimate.
pub fn select_pair(
    pa: &ConvParams,
    pb: &ConvParams,
    dev: &DeviceSpec,
    ws_budget: u64,
) -> Option<(KernelDesc, KernelDesc, f64)> {
    count_invocation();
    let cas = candidates_for(pa, dev, ws_budget);
    let cbs = candidates_for(pb, dev, ws_budget);
    let mut best: Option<(KernelDesc, KernelDesc, f64)> = None;
    for a in &cas {
        for b in &cbs {
            if a.workspace_bytes + b.workspace_bytes > ws_budget {
                continue;
            }
            let est = estimate_pair_makespan_us(a, b, dev);
            if best.as_ref().map_or(true, |(_, _, t)| est < *t) {
                best = Some((a.clone(), b.clone(), est));
            }
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::convlib::Algorithm;

    fn k40() -> DeviceSpec {
        DeviceSpec::k40()
    }

    const GB4: u64 = 4 * 1024 * 1024 * 1024;

    #[test]
    fn fastest_only_picks_fft_on_table2_conv() {
        // Paper: TensorFlow selects FFT (36 ms) for the Table 2 conv.
        let d = select_solo(
            SelectionPolicy::FastestOnly,
            &ConvParams::table2_5x5(),
            &k40(),
            u64::MAX,
        )
        .unwrap();
        assert_eq!(d.algo, Algorithm::Fft);
    }

    #[test]
    fn memory_min_picks_gemm_on_table2_conv() {
        let d = select_solo(
            SelectionPolicy::MemoryMin,
            &ConvParams::table2_5x5(),
            &k40(),
            u64::MAX,
        )
        .unwrap();
        assert_eq!(d.algo, Algorithm::Gemm); // 0 workspace
    }

    #[test]
    fn fastest_respects_budget() {
        // With a 1 GB budget the 2.2 GB FFT is inadmissible; the picked
        // algorithm must fit and be fastest among the fitting set.
        let budget = 1024 * 1024 * 1024;
        let d = select_solo(
            SelectionPolicy::FastestOnly,
            &ConvParams::table2_5x5(),
            &k40(),
            budget,
        )
        .unwrap();
        assert!(d.workspace_bytes <= budget);
        assert_eq!(d.algo, Algorithm::WinogradNonfused); // 691 MB, 46 ms
    }

    #[test]
    fn balanced_trades_time_for_memory() {
        let p = ConvParams::table2_5x5();
        let d = select_solo(SelectionPolicy::Balanced, &p, &k40(), GB4)
            .unwrap();
        // with memory in the objective, the 2.2GB FFT loses to a leaner
        // algorithm
        assert_ne!(d.algo, Algorithm::Fft);
    }

    #[test]
    fn pair_selection_finds_complementary_algos() {
        // The Table-1 scenario: the two independent inception-3a convs.
        // Profile-guided pairing must find an assignment whose estimated
        // makespan beats the best serial assignment.
        let dev = k40();
        let pa = ConvParams::incep3a_3x3(32);
        let pb = ConvParams::incep3a_5x5(32);
        let (da, db, paired) =
            select_pair(&pa, &pb, &dev, GB4).unwrap();
        let best_serial = {
            let fa = select_solo(SelectionPolicy::FastestOnly, &pa, &dev, GB4)
                .unwrap();
            let fb = select_solo(SelectionPolicy::FastestOnly, &pb, &dev, GB4)
                .unwrap();
            isolated_time_us(&fa, &dev) + isolated_time_us(&fb, &dev)
        };
        assert!(
            paired < best_serial,
            "paired {paired} vs serial {best_serial} ({} + {})",
            da.algo,
            db.algo
        );
        assert_ne!((da.algo, db.algo), (Algorithm::ImplicitPrecompGemm,
                                        Algorithm::ImplicitPrecompGemm),
                   "pairing should avoid TF's both-PRECOMP choice");
    }

    #[test]
    fn pair_estimate_bounds() {
        // paired estimate never beats max(t_a, t_b) nor exceeds t_a + t_b
        let dev = k40();
        let p = ConvParams::incep3a_3x3(32);
        let a = kernel_desc(Algorithm::ImplicitPrecompGemm, &p, &dev).unwrap();
        let b = kernel_desc(Algorithm::FftTiling, &p, &dev).unwrap();
        let est = estimate_pair_makespan_us(&a, &b, &dev);
        let ta = isolated_time_us(&a, &dev);
        let tb = isolated_time_us(&b, &dev);
        assert!(est <= ta + tb + 1e-6);
        assert!(est >= ta.max(tb) - 1e-6);
    }

    #[test]
    fn impossible_budget_returns_none() {
        // even GEMM (0 ws) fits any budget, so use budget 0 and an op where
        // all algorithms need workspace... GEMM always fits: so None never
        // happens for convs. Verify the always-Some contract instead.
        let d = select_solo(
            SelectionPolicy::FastestOnly,
            &ConvParams::incep3a_3x3(32),
            &k40(),
            0,
        );
        assert!(d.is_some()); // GEMM/DIRECT are workspace-free fallbacks
    }

    #[test]
    fn group_estimate_matches_pair_estimate_for_two() {
        let dev = k40();
        let p = ConvParams::incep3a_3x3(32);
        let a = kernel_desc(Algorithm::ImplicitPrecompGemm, &p, &dev).unwrap();
        let b = kernel_desc(Algorithm::FftTiling, &p, &dev).unwrap();
        let pair = estimate_pair_makespan_us(&a, &b, &dev);
        let group = estimate_group_makespan_us(&[&a, &b], &dev);
        assert!(
            (pair - group).abs() < 1e-6,
            "pair {pair} vs group {group}"
        );
    }

    #[test]
    fn group_estimate_degenerate_sizes() {
        let dev = k40();
        let p = ConvParams::incep3a_3x3(32);
        let a = kernel_desc(Algorithm::ImplicitPrecompGemm, &p, &dev).unwrap();
        assert_eq!(estimate_group_makespan_us(&[], &dev), 0.0);
        let one = estimate_group_makespan_us(&[&a], &dev);
        assert!((one - isolated_time_us(&a, &dev)).abs() < 1e-9);
    }

    #[test]
    fn group_estimate_bounds() {
        // group estimate never beats the longest member nor exceeds the
        // serial sum (same envelope the pair estimate honours)
        let dev = k40();
        let p3 = ConvParams::incep3a_3x3(32);
        let p5 = ConvParams::incep3a_5x5(32);
        let descs = [
            kernel_desc(Algorithm::ImplicitPrecompGemm, &p3, &dev).unwrap(),
            kernel_desc(Algorithm::FftTiling, &p3, &dev).unwrap(),
            kernel_desc(Algorithm::Gemm, &p5, &dev).unwrap(),
        ];
        let refs: Vec<&KernelDesc> = descs.iter().collect();
        let est = estimate_group_makespan_us(&refs, &dev);
        let times: Vec<f64> =
            descs.iter().map(|d| isolated_time_us(d, &dev)).collect();
        let sum: f64 = times.iter().sum();
        let max = times.iter().cloned().fold(0.0f64, f64::max);
        // a couple percent of slack: quota plans and the bandwidth factor
        // may price a hostile group slightly above back-to-back execution
        // (admission then rejects it — but the estimate itself is free to
        // say so)
        assert!(est <= sum * 1.02 + 1e-6, "est {est} > serial sum {sum}");
        assert!(est >= max - 1e-6, "est {est} < floor {max}");
    }

    #[test]
    fn group_k2_reproduces_select_pair_on_table1_shapes() {
        // The satellite contract: with k = 2 the group selector must make
        // exactly the legacy pairwise choices on the paper's shapes.
        let dev = k40();
        let pa = ConvParams::incep3a_3x3(32);
        let pb = ConvParams::incep3a_5x5(32);
        let (da, db, est) = select_pair(&pa, &pb, &dev, GB4).unwrap();
        let g = select_group(&[&pa, &pb], 2, &dev, GB4).unwrap();
        assert_eq!(g.members, vec![0, 1], "pairing did not form");
        assert_eq!(g.descs[0].algo, da.algo);
        assert_eq!(g.descs[1].algo, db.algo);
        assert!(
            (g.est_us - est).abs() <= est * 1e-9,
            "group est {} vs pair est {est}",
            g.est_us
        );
    }

    #[test]
    fn group_k2_reproduces_select_pair_on_table2_shape() {
        // Table-2 5x5 conv beside the inception 3x3: whatever select_pair
        // decides, select_group at k = 2 must agree — either the same
        // algorithm assignment, or no group because pairing does not beat
        // serial by the admission margin.
        let dev = k40();
        let pa = ConvParams::table2_5x5();
        let pb = ConvParams::incep3a_3x3(32);
        let g = select_group(&[&pa, &pb], 2, &dev, GB4).unwrap();
        if g.members.len() == 2 {
            let (da, db, est) = select_pair(&pa, &pb, &dev, GB4).unwrap();
            assert_eq!(g.descs[0].algo, da.algo);
            assert_eq!(g.descs[1].algo, db.algo);
            assert!((g.est_us - est).abs() <= est * 1e-9);
        } else {
            // group declined: the best pair must indeed miss the margin
            if let Some((_, _, est)) = select_pair(&pa, &pb, &dev, GB4) {
                assert!(est >= g.serial_us * 0.98 - 1e-6);
            }
        }
    }

    #[test]
    fn group_respects_k_and_workspace_budget() {
        let dev = k40();
        let p3 = ConvParams::incep3a_3x3(32);
        let p5 = ConvParams::incep3a_5x5(32);
        let pt = ConvParams::table2_5x5();
        let cands: Vec<&ConvParams> = vec![&p3, &p5, &pt, &p3];
        for k in [1usize, 2, 3, 4] {
            let g = select_group(&cands, k, &dev, GB4).unwrap();
            assert!(g.members.len() <= k, "k={k}: {:?}", g.members);
            assert!(g.combined_workspace() <= GB4);
            // every admitted group beats its serial baseline in estimate
            assert!(g.est_us <= g.serial_us + 1e-6);
            // members are distinct candidate indices
            let mut m = g.members.clone();
            m.sort_unstable();
            m.dedup();
            assert_eq!(m.len(), g.members.len());
        }
    }

    #[test]
    fn group_seed_only_when_no_partner_pays() {
        // Candidates that cannot gain from co-running (a single candidate)
        // yield a solo group with the fastest-solo descriptor.
        let dev = k40();
        let p = ConvParams::incep3a_3x3(32);
        let g = select_group(&[&p], 4, &dev, GB4).unwrap();
        assert_eq!(g.members, vec![0]);
        let solo = select_solo(SelectionPolicy::FastestOnly, &p, &dev, GB4)
            .unwrap();
        assert_eq!(g.descs[0].algo, solo.algo);
        assert!((g.speedup() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn policy_parsing() {
        assert_eq!(
            SelectionPolicy::parse("tensorflow"),
            Some(SelectionPolicy::FastestOnly)
        );
        assert_eq!(
            SelectionPolicy::parse("profile_guided"),
            Some(SelectionPolicy::ProfileGuided)
        );
        assert_eq!(SelectionPolicy::parse("?"), None);
    }
}
