//! Scheduler types: the shared scheduling vocabulary.
//!
//! "Selecting independent operations from the ready queue for concurrent
//! execution is a challenging scheduling problem that highly depends on the
//! network topology and resource utilization of operations" (paper §3).
//! Since the plan/execute split, that scheduling problem is solved *once*
//! per (DAG, device, config) by [`crate::plan::Planner`] and the resulting
//! [`crate::plan::Plan`] is replayed per request by
//! [`crate::plan::Session`]. This module keeps the shared vocabulary —
//! [`ScheduleConfig`], [`PriorityPolicy`], [`OpExec`], [`ScheduleResult`],
//! the non-convolution duration model. (The retired `Coordinator` alias of
//! `Session` is gone; name `Session` directly.)

use std::sync::Arc;

use crate::convlib::Algorithm;
use crate::gpusim::{DeviceSpec, PartitionMode};
use crate::graph::OpKind;

use super::selector::SelectionPolicy;

/// Ready-queue ordering policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PriorityPolicy {
    /// Arrival (BFS) order — the legacy behaviour.
    Fifo,
    /// Critical-path priority: order ready ops by *bottom level* (the
    /// cost-weighted longest path to a sink, computed once per DAG), so
    /// the chain that bounds the makespan is dispatched and grouped
    /// first and short fork branches cannot starve it.
    CriticalPath,
}

impl PriorityPolicy {
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "fifo" | "arrival" => Some(Self::Fifo),
            "critical_path" | "critical-path" | "bottom_level" => {
                Some(Self::CriticalPath)
            }
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Fifo => "fifo",
            Self::CriticalPath => "critical_path",
        }
    }
}

/// Scheduler configuration.
#[derive(Clone, Debug)]
pub struct ScheduleConfig {
    pub policy: SelectionPolicy,
    pub partition: PartitionMode,
    /// Max concurrent streams (width of one co-execution group).
    pub streams: usize,
    /// Workspace budget in bytes.
    pub workspace_limit: u64,
    /// Ready-queue ordering.
    pub priority: PriorityPolicy,
}

impl Default for ScheduleConfig {
    fn default() -> Self {
        Self {
            policy: SelectionPolicy::ProfileGuided,
            partition: PartitionMode::IntraSm,
            streams: 4,
            workspace_limit: 4 * 1024 * 1024 * 1024,
            priority: PriorityPolicy::CriticalPath,
        }
    }
}

/// Execution record of one op.
#[derive(Clone, Debug)]
pub struct OpExec {
    pub op_id: usize,
    /// Interned op name (an `Arc<str>` clone of [`crate::graph::Op::name`]
    /// — a refcount bump per record, not a heap copy).
    pub name: Arc<str>,
    pub kind: &'static str,
    pub algo: Option<Algorithm>,
    pub start_us: f64,
    pub end_us: f64,
    pub workspace_bytes: u64,
    /// Stream lane the op ran on: `Some(lane)` for convolutions (the
    /// member index of its group under barrier replay, the executor's
    /// lane under event-driven execution), `None` for ops on the serial
    /// host lane. Feeds the per-stream tracks of the Chrome-trace export.
    pub stream: Option<usize>,
    /// Where the op ran: `Some(d)` for compute and host ops on device
    /// `d` (0 for single-GPU schedules), `None` for gradient reductions,
    /// which occupy the shared interconnect lane rather than any compute
    /// device. The Chrome-trace export routes `None` to the interconnect
    /// track.
    pub device: Option<usize>,
}

/// Result of scheduling a whole DAG.
#[derive(Clone, Debug)]
pub struct ScheduleResult {
    pub makespan_us: f64,
    pub ops: Vec<OpExec>,
    /// Peak concurrent workspace use.
    pub peak_workspace: u64,
    /// Times an algorithm had to be downgraded because workspace would not
    /// fit next to concurrently running ops.
    pub ws_fallbacks: u64,
    /// Number of scheduling rounds (co-execution groups executed).
    pub rounds: u64,
    /// Wall time spent with >= 2 convs in flight.
    pub conv_overlap_us: f64,
    /// Total interconnect time spent in gradient reductions (zero for
    /// single-GPU schedules). Under the event executor this time runs on
    /// the dedicated comm lane, concurrent with compute; the makespan
    /// tells whether it was hidden.
    pub comm_us: f64,
}

/// Duration model for non-convolution ops: bandwidth-bound on the
/// device, except gradient reductions, which are priced by the ring
/// all-reduce formula of the link model they carry (the interconnect,
/// not device DRAM, is their bottleneck).
pub fn non_conv_time_us(kind: &OpKind, spec: &DeviceSpec) -> f64 {
    match kind {
        OpKind::Input => 0.0,
        OpKind::GradReduce {
            bytes,
            replicas,
            link_latency_us,
            link_gb_per_s,
        } => crate::cluster::LinkModel {
            latency_us: *link_latency_us,
            gb_per_s: *link_gb_per_s,
        }
        .ring_allreduce_us(*bytes, *replicas),
        OpKind::Collective(d) => crate::cluster::LinkModel {
            latency_us: d.step_latency_us,
            gb_per_s: d.gb_per_s,
        }
        .staged_us(d.steps, d.hop_bytes),
        OpKind::FullyConnected { .. } => {
            // small GEMM: compute at modest efficiency + overhead
            kind.flops() / (spec.peak_flops * 0.3) * 1e6
                + kind.dram_bytes() / spec.effective_bw() * 1e6
                + spec.launch_overhead_us
        }
        _ => {
            kind.dram_bytes() / spec.effective_bw() * 1e6
                + spec.launch_overhead_us
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Network;
    use crate::plan::Session;

    fn coord(
        policy: SelectionPolicy,
        partition: PartitionMode,
        streams: usize,
    ) -> Session {
        Session::new(
            DeviceSpec::k40(),
            ScheduleConfig {
                policy,
                partition,
                streams,
                workspace_limit: 4 * 1024 * 1024 * 1024,
                priority: PriorityPolicy::CriticalPath,
            },
        )
    }

    #[test]
    fn executes_every_op_exactly_once() {
        let dag = Network::GoogleNet.build(8);
        let r = coord(
            SelectionPolicy::ProfileGuided,
            PartitionMode::IntraSm,
            4,
        )
        .run(&dag);
        assert_eq!(r.ops.len(), dag.len());
        let mut ids: Vec<usize> = r.ops.iter().map(|o| o.op_id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), dag.len());
    }

    #[test]
    fn dependencies_respected() {
        let dag = Network::GoogleNet.build(4);
        let r = coord(
            SelectionPolicy::ProfileGuided,
            PartitionMode::IntraSm,
            4,
        )
        .run(&dag);
        let mut end: Vec<f64> = vec![0.0; dag.len()];
        let mut start: Vec<f64> = vec![0.0; dag.len()];
        for o in &r.ops {
            end[o.op_id] = o.end_us;
            start[o.op_id] = o.start_us;
        }
        for i in 0..dag.len() {
            for &p in dag.preds(i) {
                assert!(
                    end[p] <= start[i] + 1e-6,
                    "op {i} started before pred {p} finished"
                );
            }
        }
    }

    #[test]
    fn concurrent_beats_serial_on_googlenet() {
        // E6 headline: profile-guided + intra-SM < TF-style serial.
        let dag = Network::GoogleNet.build(32);
        let serial = coord(
            SelectionPolicy::FastestOnly,
            PartitionMode::Serial,
            1,
        )
        .run(&dag);
        let conc = coord(
            SelectionPolicy::ProfileGuided,
            PartitionMode::IntraSm,
            2,
        )
        .run(&dag);
        assert!(
            conc.makespan_us < serial.makespan_us,
            "concurrent {} >= serial {}",
            conc.makespan_us,
            serial.makespan_us
        );
        assert!(conc.conv_overlap_us > 0.0);
    }

    #[test]
    fn alexnet_gains_nothing() {
        // Linear network: no independent convs, so policies tie (modulo
        // algorithm choices) and overlap is zero.
        let dag = Network::AlexNet.build(32);
        let conc = coord(
            SelectionPolicy::FastestOnly,
            PartitionMode::IntraSm,
            4,
        )
        .run(&dag);
        assert_eq!(conc.conv_overlap_us, 0.0);
    }

    #[test]
    fn workspace_budget_forces_fallbacks() {
        let dag = Network::GoogleNet.build(32);
        let tight = Session::new(
            DeviceSpec::k40(),
            ScheduleConfig {
                policy: SelectionPolicy::FastestOnly,
                partition: PartitionMode::Serial,
                streams: 1,
                workspace_limit: 16 * 1024 * 1024, // 16 MB
                priority: PriorityPolicy::CriticalPath,
            },
        )
        .run(&dag);
        assert!(tight.ws_fallbacks > 0);
        assert!(tight.peak_workspace <= 16 * 1024 * 1024);
        // loose budget: no fallbacks
        let loose = coord(
            SelectionPolicy::FastestOnly,
            PartitionMode::Serial,
            1,
        )
        .run(&dag);
        assert!(loose.makespan_us <= tight.makespan_us * 1.01);
    }

    #[test]
    fn grad_reduce_priced_by_its_link_model_not_dram() {
        let spec = DeviceSpec::k40();
        let kind = OpKind::GradReduce {
            bytes: 24_000_000,
            replicas: 4,
            link_latency_us: 10.0,
            link_gb_per_s: 12.0,
        };
        let t = non_conv_time_us(&kind, &spec);
        let expect = crate::cluster::LinkModel {
            latency_us: 10.0,
            gb_per_s: 12.0,
        }
        .ring_allreduce_us(24_000_000, 4);
        assert_eq!(t, expect);
        // a one-replica reduce is free (and never emitted anyway)
        let solo = OpKind::GradReduce {
            bytes: 24_000_000,
            replicas: 1,
            link_latency_us: 10.0,
            link_gb_per_s: 12.0,
        };
        assert_eq!(non_conv_time_us(&solo, &spec), 0.0);
    }

    #[test]
    fn collectives_priced_by_their_routed_path_not_dram() {
        use crate::graph::{CollectiveKind, CommDesc};
        let spec = DeviceSpec::k40();
        let kind = OpKind::Collective(CommDesc {
            coll: CollectiveKind::AllGather,
            bytes: 24_000_000,
            group: vec![0, 1, 2, 3],
            steps: 3,
            step_latency_us: 5.0,
            hop_bytes: 6_000_000.0,
            gb_per_s: 60.0,
            links: vec![0, 1, 2, 3],
        });
        let t = non_conv_time_us(&kind, &spec);
        let expect = crate::cluster::LinkModel {
            latency_us: 5.0,
            gb_per_s: 60.0,
        }
        .staged_us(3, 6_000_000.0);
        assert_eq!(t, expect);
        assert!(t > 0.0);
        // a zero-step collective (degenerate group) is free
        let solo = OpKind::Collective(CommDesc {
            coll: CollectiveKind::ReduceScatter,
            bytes: 24_000_000,
            group: vec![0],
            steps: 0,
            step_latency_us: 5.0,
            hop_bytes: 0.0,
            gb_per_s: 60.0,
            links: vec![],
        });
        assert_eq!(non_conv_time_us(&solo, &spec), 0.0);
    }

    #[test]
    fn priority_policy_parses() {
        assert_eq!(
            PriorityPolicy::parse("critical_path"),
            Some(PriorityPolicy::CriticalPath)
        );
        assert_eq!(
            PriorityPolicy::parse("bottom_level"),
            Some(PriorityPolicy::CriticalPath)
        );
        assert_eq!(PriorityPolicy::parse("fifo"), Some(PriorityPolicy::Fifo));
        assert_eq!(PriorityPolicy::parse("?"), None);
        assert_eq!(PriorityPolicy::CriticalPath.name(), "critical_path");
    }

    #[test]
    fn fifo_and_critical_path_both_schedule_correctly() {
        // Priority changes the order, never the correctness: both
        // policies execute every op once and respect dependencies.
        let dag = Network::GoogleNet.build(8);
        for priority in [PriorityPolicy::Fifo, PriorityPolicy::CriticalPath] {
            let r = Session::new(
                DeviceSpec::k40(),
                ScheduleConfig {
                    policy: SelectionPolicy::ProfileGuided,
                    partition: PartitionMode::IntraSm,
                    streams: 4,
                    workspace_limit: 4 * 1024 * 1024 * 1024,
                    priority,
                },
            )
            .run(&dag);
            assert_eq!(r.ops.len(), dag.len(), "{priority:?}");
        }
    }

    #[test]
    fn wide_streams_schedule_googlenet_with_overlap() {
        // k-wide rounds: 4 streams on a 4-branch-wide network must still
        // produce overlap and beat the serial baseline.
        let dag = Network::GoogleNet.build(32);
        let serial = coord(
            SelectionPolicy::FastestOnly,
            PartitionMode::Serial,
            1,
        )
        .run(&dag);
        let wide = coord(
            SelectionPolicy::ProfileGuided,
            PartitionMode::IntraSm,
            4,
        )
        .run(&dag);
        assert!(wide.conv_overlap_us > 0.0);
        assert!(
            wide.makespan_us < serial.makespan_us,
            "wide {} >= serial {}",
            wide.makespan_us,
            serial.makespan_us
        );
    }

    #[test]
    fn peak_workspace_tracks_concurrency() {
        let dag = Network::GoogleNet.build(32);
        let serial = coord(
            SelectionPolicy::FastestOnly,
            PartitionMode::Serial,
            1,
        )
        .run(&dag);
        let conc = coord(
            SelectionPolicy::FastestOnly,
            PartitionMode::StreamsOnly,
            4,
        )
        .run(&dag);
        // running 4 convs at once cannot use less peak workspace
        assert!(conc.peak_workspace >= serial.peak_workspace);
    }

    #[test]
    fn session_caches_across_runs() {
        let c = coord(
            SelectionPolicy::ProfileGuided,
            PartitionMode::IntraSm,
            2,
        );
        let dag = Network::GoogleNet.build(8);
        c.run(&dag);
        c.run(&dag);
        let stats = c.stats();
        assert_eq!(stats.plans_built, 1);
        assert_eq!(stats.cache_hits, 1);
        assert_eq!(c.spec().name, "Tesla K40");
        assert_eq!(c.config().streams, 2);
    }
}
