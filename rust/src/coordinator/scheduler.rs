//! The DAG scheduler: ready-queue execution of a network over the GPU
//! simulator, with policy-driven algorithm selection and workspace-aware
//! admission.
//!
//! "Selecting independent operations from the ready queue for concurrent
//! execution is a challenging scheduling problem that highly depends on the
//! network topology and resource utilization of operations" (paper §3) —
//! this module is that scheduler.

use std::cell::RefCell;
use std::collections::{HashMap, VecDeque};

use crate::convlib::{Algorithm, ConvParams, KernelDesc};
use crate::graph::{Dag, OpKind};
use crate::gpusim::{
    isolated_time_us, DeviceSpec, Engine, PartitionMode, SimResult,
};
use crate::memory::DeviceMemory;

use super::selector::{select_group, select_solo, SelectionPolicy};

/// Ready-queue ordering policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PriorityPolicy {
    /// Arrival (BFS) order — the legacy behaviour.
    Fifo,
    /// Critical-path priority: order ready ops by *bottom level* (the
    /// cost-weighted longest path to a sink, computed once per DAG), so
    /// the chain that bounds the makespan is dispatched and grouped
    /// first and short fork branches cannot starve it.
    CriticalPath,
}

impl PriorityPolicy {
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "fifo" | "arrival" => Some(Self::Fifo),
            "critical_path" | "critical-path" | "bottom_level" => {
                Some(Self::CriticalPath)
            }
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Fifo => "fifo",
            Self::CriticalPath => "critical_path",
        }
    }
}

/// Scheduler configuration.
#[derive(Clone, Debug)]
pub struct ScheduleConfig {
    pub policy: SelectionPolicy,
    pub partition: PartitionMode,
    /// Max concurrent streams (width of one co-execution group).
    pub streams: usize,
    /// Workspace budget in bytes.
    pub workspace_limit: u64,
    /// Ready-queue ordering.
    pub priority: PriorityPolicy,
}

impl Default for ScheduleConfig {
    fn default() -> Self {
        Self {
            policy: SelectionPolicy::ProfileGuided,
            partition: PartitionMode::IntraSm,
            streams: 4,
            workspace_limit: 4 * 1024 * 1024 * 1024,
            priority: PriorityPolicy::CriticalPath,
        }
    }
}

/// Execution record of one op.
#[derive(Clone, Debug)]
pub struct OpExec {
    pub op_id: usize,
    pub name: String,
    pub kind: &'static str,
    pub algo: Option<Algorithm>,
    pub start_us: f64,
    pub end_us: f64,
    pub workspace_bytes: u64,
}

/// Result of scheduling a whole DAG.
#[derive(Clone, Debug)]
pub struct ScheduleResult {
    pub makespan_us: f64,
    pub ops: Vec<OpExec>,
    /// Peak concurrent workspace use.
    pub peak_workspace: u64,
    /// Times an algorithm had to be downgraded because workspace would not
    /// fit next to concurrently running ops.
    pub ws_fallbacks: u64,
    /// Number of scheduling rounds (engine invocations).
    pub rounds: u64,
    /// Wall time spent with >= 2 convs in flight.
    pub conv_overlap_us: f64,
}

/// The coordinator: owns the device spec and config, executes DAGs.
pub struct Coordinator {
    spec: DeviceSpec,
    cfg: ScheduleConfig,
    /// Optional (rate, seed) for workspace-allocation failure injection.
    failure_injection: Option<(f64, u64)>,
    /// Memoized unconstrained solo selections: repeated convolutions (the
    /// same shape appears dozens of times per network) probe the
    /// seven-algorithm space once. Perf opt, see EXPERIMENTS.md §Perf.
    solo_cache:
        RefCell<HashMap<(ConvParams, SelectionPolicy), KernelDesc>>,
}

impl Coordinator {
    pub fn new(spec: DeviceSpec, cfg: ScheduleConfig) -> Self {
        Self {
            spec,
            cfg,
            failure_injection: None,
            solo_cache: RefCell::new(HashMap::new()),
        }
    }

    /// Coordinator whose workspace allocator spuriously refuses a `rate`
    /// fraction of allocations (robustness testing: the scheduler must
    /// degrade to workspace-free algorithms, never fail an op).
    pub fn with_failure_injection(
        spec: DeviceSpec,
        cfg: ScheduleConfig,
        rate: f64,
        seed: u64,
    ) -> Self {
        let mut c = Self::new(spec, cfg);
        c.failure_injection = Some((rate, seed));
        c
    }

    /// Memoized `select_solo` with an unlimited budget.
    fn solo_unconstrained(
        &self,
        policy: SelectionPolicy,
        p: &ConvParams,
    ) -> KernelDesc {
        if let Some(d) =
            self.solo_cache.borrow().get(&(p.clone(), policy))
        {
            return d.clone();
        }
        let d = select_solo(policy, p, &self.spec, u64::MAX)
            .expect("some algorithm always supported");
        self.solo_cache
            .borrow_mut()
            .insert((p.clone(), policy), d.clone());
        d
    }

    pub fn spec(&self) -> &DeviceSpec {
        &self.spec
    }

    pub fn config(&self) -> &ScheduleConfig {
        &self.cfg
    }

    /// Execute the DAG: returns the simulated timeline.
    pub fn execute_dag(&self, dag: &Dag) -> ScheduleResult {
        let mut indeg: Vec<usize> =
            (0..dag.len()).map(|i| dag.preds(i).len()).collect();
        let mut ready: VecDeque<usize> = (0..dag.len())
            .filter(|&i| indeg[i] == 0)
            .collect();
        let mut mem = match self.failure_injection {
            Some((rate, seed)) => DeviceMemory::with_failure_injection(
                self.cfg.workspace_limit,
                rate,
                seed,
            ),
            None => DeviceMemory::new(self.cfg.workspace_limit),
        };
        // Critical-path (bottom-level) priorities, computed once per DAG
        // from the fastest-solo cost model (Fifo never reads them, so it
        // skips the cost-model sweep).
        let bl = if self.cfg.priority == PriorityPolicy::CriticalPath {
            self.bottom_levels(dag)
        } else {
            Vec::new()
        };
        let mut clock = 0.0f64;
        let mut ops: Vec<OpExec> = Vec::with_capacity(dag.len());
        let mut ws_fallbacks = 0u64;
        let mut rounds = 0u64;
        let mut conv_overlap_us = 0.0f64;
        let mut done = vec![false; dag.len()];

        while !ready.is_empty() {
            // Partition the ready set into convs and cheap ops.
            let round: Vec<usize> = ready.drain(..).collect();
            let mut convs: Vec<usize> = Vec::new();
            for &id in &round {
                match &dag.ops[id].kind {
                    OpKind::Conv(_) => convs.push(id),
                    kind => {
                        // bandwidth-bound ops run back-to-back (negligible
                        // concurrency value; cuDNN launches them serially)
                        let dur = non_conv_time_us(kind, &self.spec);
                        ops.push(OpExec {
                            op_id: id,
                            name: dag.ops[id].name.clone(),
                            kind: kind.kind_name(),
                            algo: None,
                            start_us: clock,
                            end_us: clock + dur,
                            workspace_bytes: 0,
                        });
                        clock += dur;
                    }
                }
            }

            // Order ready convs by the configured priority, then pack
            // them into co-execution groups of at most `streams` ops.
            if self.cfg.priority == PriorityPolicy::CriticalPath {
                convs.sort_by(|&a, &b| {
                    bl[b]
                        .partial_cmp(&bl[a])
                        .unwrap()
                        .then(a.cmp(&b))
                });
            }
            let mut pending: VecDeque<usize> = convs.into();
            while !pending.is_empty() {
                rounds += 1;
                let (batch, descs, mode) = self.plan_batch(
                    dag,
                    &mut pending,
                    &mem,
                    &mut ws_fallbacks,
                );
                let (sim, allocs, ran) =
                    self.run_batch(&descs, mode, &mut mem, &mut ws_fallbacks);
                for ((id, desc), rec) in
                    batch.iter().zip(&ran).zip(&sim.kernels)
                {
                    ops.push(OpExec {
                        op_id: *id,
                        name: dag.ops[*id].name.clone(),
                        kind: "conv",
                        algo: Some(desc.algo),
                        start_us: clock + rec.start_us,
                        end_us: clock + rec.end_us,
                        workspace_bytes: desc.workspace_bytes,
                    });
                }
                conv_overlap_us += sim.overlap_us();
                clock += sim.makespan_us;
                for a in allocs {
                    mem.free(a).expect("workspace free");
                }
            }

            // Mark round done, release successors.
            for &id in &round {
                done[id] = true;
            }
            for &id in &round {
                for &s in dag.succs(id) {
                    indeg[s] -= 1;
                    if indeg[s] == 0 && !done[s] {
                        ready.push_back(s);
                    }
                }
            }
        }

        debug_assert!(done.iter().all(|&d| d), "unscheduled ops (cycle?)");
        ScheduleResult {
            makespan_us: clock,
            ops,
            peak_workspace: mem.peak(),
            ws_fallbacks,
            rounds,
            conv_overlap_us,
        }
    }

    /// Bottom-level priority of every op: longest cost-weighted path to a
    /// sink under the fastest-solo cost model (convs) / bandwidth model
    /// (everything else). One reverse topological sweep per DAG.
    fn bottom_levels(&self, dag: &Dag) -> Vec<f64> {
        let cost: Vec<f64> = (0..dag.len())
            .map(|i| match &dag.ops[i].kind {
                OpKind::Conv(p) => {
                    let d = self
                        .solo_unconstrained(SelectionPolicy::FastestOnly, p);
                    isolated_time_us(&d, &self.spec)
                }
                kind => non_conv_time_us(kind, &self.spec),
            })
            .collect();
        dag.bottom_levels(&cost)
    }

    /// Take the next co-execution batch off the priority-ordered pending
    /// conv queue: the ops to run, their algorithms, and the partition
    /// mode to run them under.
    ///
    /// `ProfileGuided` packs a k-wide group via [`select_group`]: the
    /// highest-priority conv seeds the group and partners join only when
    /// the fluid-model estimate beats serializing them — the paper's
    /// "profile-based algorithm selection has to evaluate multiple
    /// metrics for optimal parallelism" (§3), generalized from pairs to
    /// `streams`-wide groups. When no partner pays, the seed runs solo on
    /// its fastest fitting algorithm, so guided scheduling can never
    /// regress. Other policies chunk up to `streams` convs in priority
    /// order and let the partition mode decide the concurrency (the
    /// TensorFlow-style baseline).
    fn plan_batch(
        &self,
        dag: &Dag,
        pending: &mut VecDeque<usize>,
        mem: &DeviceMemory,
        ws_fallbacks: &mut u64,
    ) -> (Vec<usize>, Vec<KernelDesc>, PartitionMode) {
        let conv_params = |id: usize| match &dag.ops[id].kind {
            OpKind::Conv(p) => p,
            _ => unreachable!("pending contains non-conv"),
        };
        let budget = mem.available();
        let k = self.cfg.streams.max(1);
        if self.cfg.policy == SelectionPolicy::ProfileGuided
            && k >= 2
            && pending.len() >= 2
        {
            let ids: Vec<usize> = pending.iter().copied().collect();
            let params: Vec<&ConvParams> =
                ids.iter().map(|&id| conv_params(id)).collect();
            if let Some(g) = select_group(&params, k, &self.spec, budget) {
                if g.members.len() >= 2 {
                    let batch: Vec<usize> =
                        g.members.iter().map(|&m| ids[m]).collect();
                    pending.retain(|id| !batch.contains(id));
                    return (batch, g.descs, self.cfg.partition);
                }
            }
            // no partner pays off: the seed runs alone, serially
            let id = pending.pop_front().expect("pending non-empty");
            let descs =
                self.solo_batch(&[conv_params(id)], budget, ws_fallbacks);
            return (vec![id], descs, PartitionMode::Serial);
        }
        let take = k.min(pending.len());
        let batch: Vec<usize> = pending.drain(..take).collect();
        let params: Vec<&ConvParams> =
            batch.iter().map(|&id| conv_params(id)).collect();
        let descs = self.solo_batch(&params, budget, ws_fallbacks);
        (batch, descs, self.cfg.partition)
    }

    fn solo_batch(
        &self,
        params: &[&ConvParams],
        mut budget: u64,
        ws_fallbacks: &mut u64,
    ) -> Vec<KernelDesc> {
        // Sequential admission: each op's workspace shrinks the budget the
        // next sees (launch-time memory check, paper §2 footnote 1).
        // ProfileGuided ops running solo take the fastest fitting algorithm
        // (complementarity is meaningless without a partner).
        let policy = match self.cfg.policy {
            SelectionPolicy::ProfileGuided => SelectionPolicy::FastestOnly,
            p => p,
        };
        let mut out = Vec::with_capacity(params.len());
        for p in params {
            let unconstrained = self.solo_unconstrained(policy, p);
            let fitted = if unconstrained.workspace_bytes <= budget {
                unconstrained.clone()
            } else {
                select_solo(policy, p, &self.spec, budget)
                    .expect("GEMM fallback always fits")
            };
            if fitted.algo != unconstrained.algo {
                *ws_fallbacks += 1;
            }
            budget = budget.saturating_sub(fitted.workspace_bytes);
            out.push(fitted);
        }
        out
    }

    /// Simulate one batch; workspace is held for the batch duration.
    /// Returns the timeline, the live allocation ids, and the descriptors
    /// that actually ran (fallback downgrades included), so the caller's
    /// execution records never misattribute algorithm or workspace.
    fn run_batch(
        &self,
        descs: &[KernelDesc],
        mode: PartitionMode,
        mem: &mut DeviceMemory,
        ws_fallbacks: &mut u64,
    ) -> (SimResult, Vec<u64>, Vec<KernelDesc>) {
        // Graceful degradation: if an admission-checked allocation still
        // fails (failure injection / fragmentation), downgrade that op to
        // its workspace-free fallback rather than failing the schedule —
        // mirroring frameworks falling back when cudaMalloc refuses.
        let mut final_descs: Vec<KernelDesc> = Vec::with_capacity(descs.len());
        let mut allocs = Vec::with_capacity(descs.len());
        for d in descs {
            match mem.alloc(d.workspace_bytes) {
                Ok(id) => {
                    allocs.push(id);
                    final_descs.push(d.clone());
                }
                Err(_) => {
                    let fallback = crate::convlib::kernel_desc(
                        Algorithm::Gemm,
                        &d.params,
                        &self.spec,
                    )
                    .expect("GEMM supports every convolution");
                    debug_assert_eq!(fallback.workspace_bytes, 0);
                    if fallback.algo != d.algo {
                        *ws_fallbacks += 1;
                    }
                    final_descs.push(fallback);
                }
            }
        }
        let mode = if final_descs.len() <= 1 {
            PartitionMode::Serial
        } else {
            mode
        };
        let mut engine = Engine::new(self.spec.clone(), mode);
        for (i, d) in final_descs.iter().enumerate() {
            let stream = match mode {
                PartitionMode::Serial => 0,
                _ => i,
            };
            engine.launch(d.clone(), stream);
        }
        (engine.run(), allocs, final_descs)
    }
}

/// Duration model for non-convolution ops: bandwidth-bound.
pub fn non_conv_time_us(kind: &OpKind, spec: &DeviceSpec) -> f64 {
    match kind {
        OpKind::Input => 0.0,
        OpKind::FullyConnected { .. } => {
            // small GEMM: compute at modest efficiency + overhead
            kind.flops() / (spec.peak_flops * 0.3) * 1e6
                + kind.dram_bytes() / spec.effective_bw() * 1e6
                + spec.launch_overhead_us
        }
        _ => {
            kind.dram_bytes() / spec.effective_bw() * 1e6
                + spec.launch_overhead_us
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Network;

    fn coord(
        policy: SelectionPolicy,
        partition: PartitionMode,
        streams: usize,
    ) -> Coordinator {
        Coordinator::new(
            DeviceSpec::k40(),
            ScheduleConfig {
                policy,
                partition,
                streams,
                workspace_limit: 4 * 1024 * 1024 * 1024,
                priority: PriorityPolicy::CriticalPath,
            },
        )
    }

    #[test]
    fn executes_every_op_exactly_once() {
        let dag = Network::GoogleNet.build(8);
        let r = coord(
            SelectionPolicy::ProfileGuided,
            PartitionMode::IntraSm,
            4,
        )
        .execute_dag(&dag);
        assert_eq!(r.ops.len(), dag.len());
        let mut ids: Vec<usize> = r.ops.iter().map(|o| o.op_id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), dag.len());
    }

    #[test]
    fn dependencies_respected() {
        let dag = Network::GoogleNet.build(4);
        let r = coord(
            SelectionPolicy::ProfileGuided,
            PartitionMode::IntraSm,
            4,
        )
        .execute_dag(&dag);
        let mut end: Vec<f64> = vec![0.0; dag.len()];
        let mut start: Vec<f64> = vec![0.0; dag.len()];
        for o in &r.ops {
            end[o.op_id] = o.end_us;
            start[o.op_id] = o.start_us;
        }
        for i in 0..dag.len() {
            for &p in dag.preds(i) {
                assert!(
                    end[p] <= start[i] + 1e-6,
                    "op {i} started before pred {p} finished"
                );
            }
        }
    }

    #[test]
    fn concurrent_beats_serial_on_googlenet() {
        // E6 headline: profile-guided + intra-SM < TF-style serial.
        let dag = Network::GoogleNet.build(32);
        let serial = coord(
            SelectionPolicy::FastestOnly,
            PartitionMode::Serial,
            1,
        )
        .execute_dag(&dag);
        let conc = coord(
            SelectionPolicy::ProfileGuided,
            PartitionMode::IntraSm,
            2,
        )
        .execute_dag(&dag);
        assert!(
            conc.makespan_us < serial.makespan_us,
            "concurrent {} >= serial {}",
            conc.makespan_us,
            serial.makespan_us
        );
        assert!(conc.conv_overlap_us > 0.0);
    }

    #[test]
    fn alexnet_gains_nothing() {
        // Linear network: no independent convs, so policies tie (modulo
        // algorithm choices) and overlap is zero.
        let dag = Network::AlexNet.build(32);
        let conc = coord(
            SelectionPolicy::FastestOnly,
            PartitionMode::IntraSm,
            4,
        )
        .execute_dag(&dag);
        assert_eq!(conc.conv_overlap_us, 0.0);
    }

    #[test]
    fn workspace_budget_forces_fallbacks() {
        let dag = Network::GoogleNet.build(32);
        let tight = Coordinator::new(
            DeviceSpec::k40(),
            ScheduleConfig {
                policy: SelectionPolicy::FastestOnly,
                partition: PartitionMode::Serial,
                streams: 1,
                workspace_limit: 16 * 1024 * 1024, // 16 MB
                priority: PriorityPolicy::CriticalPath,
            },
        )
        .execute_dag(&dag);
        assert!(tight.ws_fallbacks > 0);
        assert!(tight.peak_workspace <= 16 * 1024 * 1024);
        // loose budget: no fallbacks
        let loose = coord(
            SelectionPolicy::FastestOnly,
            PartitionMode::Serial,
            1,
        )
        .execute_dag(&dag);
        assert!(loose.makespan_us <= tight.makespan_us * 1.01);
    }

    #[test]
    fn priority_policy_parses() {
        assert_eq!(
            PriorityPolicy::parse("critical_path"),
            Some(PriorityPolicy::CriticalPath)
        );
        assert_eq!(
            PriorityPolicy::parse("bottom_level"),
            Some(PriorityPolicy::CriticalPath)
        );
        assert_eq!(PriorityPolicy::parse("fifo"), Some(PriorityPolicy::Fifo));
        assert_eq!(PriorityPolicy::parse("?"), None);
        assert_eq!(PriorityPolicy::CriticalPath.name(), "critical_path");
    }

    #[test]
    fn fifo_and_critical_path_both_schedule_correctly() {
        // Priority changes the order, never the correctness: both
        // policies execute every op once and respect dependencies.
        let dag = Network::GoogleNet.build(8);
        for priority in [PriorityPolicy::Fifo, PriorityPolicy::CriticalPath] {
            let r = Coordinator::new(
                DeviceSpec::k40(),
                ScheduleConfig {
                    policy: SelectionPolicy::ProfileGuided,
                    partition: PartitionMode::IntraSm,
                    streams: 4,
                    workspace_limit: 4 * 1024 * 1024 * 1024,
                    priority,
                },
            )
            .execute_dag(&dag);
            assert_eq!(r.ops.len(), dag.len(), "{priority:?}");
        }
    }

    #[test]
    fn wide_streams_schedule_googlenet_with_overlap() {
        // k-wide rounds: 4 streams on a 4-branch-wide network must still
        // produce overlap and beat the serial baseline.
        let dag = Network::GoogleNet.build(32);
        let serial = coord(
            SelectionPolicy::FastestOnly,
            PartitionMode::Serial,
            1,
        )
        .execute_dag(&dag);
        let wide = coord(
            SelectionPolicy::ProfileGuided,
            PartitionMode::IntraSm,
            4,
        )
        .execute_dag(&dag);
        assert!(wide.conv_overlap_us > 0.0);
        assert!(
            wide.makespan_us < serial.makespan_us,
            "wide {} >= serial {}",
            wide.makespan_us,
            serial.makespan_us
        );
    }

    #[test]
    fn peak_workspace_tracks_concurrency() {
        let dag = Network::GoogleNet.build(32);
        let serial = coord(
            SelectionPolicy::FastestOnly,
            PartitionMode::Serial,
            1,
        )
        .execute_dag(&dag);
        let conc = coord(
            SelectionPolicy::FastestOnly,
            PartitionMode::StreamsOnly,
            4,
        )
        .execute_dag(&dag);
        // running 4 convs at once cannot use less peak workspace
        assert!(conc.peak_workspace >= serial.peak_workspace);
    }
}
