//! Complementary-pair discovery: the paper's "we discover 27 similar cases
//! in this network [GoogleNet] and more instances in other popular
//! non-linear CNNs such as ResNet" (§2.1).
//!
//! For every pair of *independent* convolutions in a network DAG, search
//! the algorithm-assignment space for one whose intra-SM co-execution is
//! estimated to beat the best serial execution, subject to the combined
//! workspace fitting the budget.

use crate::convlib::{Algorithm, ConvParams};
use crate::graph::{Dag, OpKind};
use crate::gpusim::{isolated_time_us, DeviceSpec};

use super::selector::{select_pair, select_solo, SelectionPolicy};

/// One discovered co-execution opportunity.
#[derive(Clone, Debug)]
pub struct PairFinding {
    pub op_a: usize,
    pub op_b: usize,
    pub name_a: String,
    pub name_b: String,
    pub algo_a: Algorithm,
    pub algo_b: Algorithm,
    /// Best-serial baseline (fastest algorithm for each, run back-to-back).
    pub serial_us: f64,
    /// Estimated co-run makespan with the discovered assignment.
    pub paired_us: f64,
    pub combined_workspace: u64,
}

impl PairFinding {
    pub fn speedup(&self) -> f64 {
        self.serial_us / self.paired_us
    }
}

/// Scan a network for complementary convolution pairs.
///
/// `min_speedup` filters findings (the paper counts cases where
/// parallelization "can improve resource utilization and reduce latency").
pub fn discover_pairs(
    dag: &Dag,
    dev: &DeviceSpec,
    ws_budget: u64,
    min_speedup: f64,
) -> Vec<PairFinding> {
    let mut findings = Vec::new();
    for (a, b) in dag.independent_conv_pairs() {
        let (pa, pb) = match (&dag.ops[a].kind, &dag.ops[b].kind) {
            (OpKind::Conv(pa), OpKind::Conv(pb)) => (pa, pb),
            _ => continue,
        };
        let serial = best_serial_us(pa, pb, dev, ws_budget);
        let Some((da, db, paired)) = select_pair(pa, pb, dev, ws_budget)
        else {
            continue;
        };
        if serial / paired >= min_speedup {
            findings.push(PairFinding {
                op_a: a,
                op_b: b,
                name_a: dag.ops[a].name.clone(),
                name_b: dag.ops[b].name.clone(),
                algo_a: da.algo,
                algo_b: db.algo,
                serial_us: serial,
                paired_us: paired,
                combined_workspace: da.workspace_bytes + db.workspace_bytes,
            });
        }
    }
    findings.sort_by(|x, y| y.speedup().partial_cmp(&x.speedup()).unwrap());
    findings
}

fn best_serial_us(
    pa: &ConvParams,
    pb: &ConvParams,
    dev: &DeviceSpec,
    ws_budget: u64,
) -> f64 {
    let ta = select_solo(SelectionPolicy::FastestOnly, pa, dev, ws_budget)
        .map(|d| isolated_time_us(&d, dev))
        .unwrap_or(f64::INFINITY);
    let tb = select_solo(SelectionPolicy::FastestOnly, pb, dev, ws_budget)
        .map(|d| isolated_time_us(&d, dev))
        .unwrap_or(f64::INFINITY);
    ta + tb
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Network;

    const GB4: u64 = 4 * 1024 * 1024 * 1024;

    #[test]
    fn googlenet_has_at_least_27_cases() {
        // The paper's §2.1 count: "We discover 27 similar cases in this
        // network".
        let dag = Network::GoogleNet.build(32);
        let findings =
            discover_pairs(&dag, &DeviceSpec::k40(), GB4, 1.05);
        assert!(
            findings.len() >= 27,
            "only {} complementary pairs found",
            findings.len()
        );
    }

    #[test]
    fn resnet_has_instances_too() {
        // "... and more instances in other popular non-linear CNNs such as
        // ResNet."
        let dag = Network::ResNet50.build(32);
        let findings =
            discover_pairs(&dag, &DeviceSpec::k40(), GB4, 1.05);
        assert!(!findings.is_empty());
    }

    #[test]
    fn alexnet_has_none() {
        let dag = Network::AlexNet.build(32);
        let findings =
            discover_pairs(&dag, &DeviceSpec::k40(), GB4, 1.0);
        assert!(findings.is_empty());
    }

    #[test]
    fn findings_sorted_and_beneficial() {
        let dag = Network::GoogleNet.build(32);
        let findings =
            discover_pairs(&dag, &DeviceSpec::k40(), GB4, 1.05);
        for w in findings.windows(2) {
            assert!(w[0].speedup() >= w[1].speedup());
        }
        for f in &findings {
            assert!(f.speedup() >= 1.05);
            assert!(f.combined_workspace <= GB4);
            assert!(dag.independent(f.op_a, f.op_b));
        }
    }

    #[test]
    fn budget_shrinks_findings() {
        let dag = Network::GoogleNet.build(32);
        let dev = DeviceSpec::k40();
        let loose = discover_pairs(&dag, &dev, GB4, 1.05).len();
        let tight =
            discover_pairs(&dag, &dev, 8 * 1024 * 1024, 1.05).len();
        assert!(tight <= loose);
    }
}
