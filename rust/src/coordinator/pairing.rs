//! Complementary-pair (and k-wide group) discovery: the paper's "we
//! discover 27 similar cases in this network [GoogleNet] and more
//! instances in other popular non-linear CNNs such as ResNet" (§2.1).
//!
//! For every pair of *independent* convolutions in a network DAG, search
//! the algorithm-assignment space for one whose intra-SM co-execution is
//! estimated to beat the best serial execution, subject to the combined
//! workspace fitting the budget. [`discover_groups`] generalizes the
//! census to `k`-wide co-execution groups over each antichain of
//! same-level convolutions (the inception-style branch sets).

use crate::convlib::{Algorithm, ConvParams};
use crate::graph::{Dag, OpKind};
use crate::gpusim::{isolated_time_us, DeviceSpec};

use super::selector::{
    select_group, select_pair, select_solo, SelectionPolicy,
};

/// One discovered co-execution opportunity.
#[derive(Clone, Debug)]
pub struct PairFinding {
    pub op_a: usize,
    pub op_b: usize,
    pub name_a: String,
    pub name_b: String,
    pub algo_a: Algorithm,
    pub algo_b: Algorithm,
    /// Best-serial baseline (fastest algorithm for each, run back-to-back).
    pub serial_us: f64,
    /// Estimated co-run makespan with the discovered assignment.
    pub paired_us: f64,
    pub combined_workspace: u64,
}

impl PairFinding {
    pub fn speedup(&self) -> f64 {
        self.serial_us / self.paired_us
    }
}

/// Scan a network for complementary convolution pairs.
///
/// `min_speedup` filters findings (the paper counts cases where
/// parallelization "can improve resource utilization and reduce latency").
pub fn discover_pairs(
    dag: &Dag,
    dev: &DeviceSpec,
    ws_budget: u64,
    min_speedup: f64,
) -> Vec<PairFinding> {
    let mut findings = Vec::new();
    for (a, b) in dag.independent_conv_pairs() {
        let (pa, pb) = match (&dag.ops[a].kind, &dag.ops[b].kind) {
            (OpKind::Conv(pa), OpKind::Conv(pb)) => (pa, pb),
            _ => continue,
        };
        let serial = best_serial_us(pa, pb, dev, ws_budget);
        let Some((da, db, paired)) = select_pair(pa, pb, dev, ws_budget)
        else {
            continue;
        };
        if serial / paired >= min_speedup {
            findings.push(PairFinding {
                op_a: a,
                op_b: b,
                name_a: dag.ops[a].name.to_string(),
                name_b: dag.ops[b].name.to_string(),
                algo_a: da.algo,
                algo_b: db.algo,
                serial_us: serial,
                paired_us: paired,
                combined_workspace: da.workspace_bytes + db.workspace_bytes,
            });
        }
    }
    findings.sort_by(|x, y| y.speedup().partial_cmp(&x.speedup()).unwrap());
    findings
}

/// One discovered k-wide co-execution opportunity.
#[derive(Clone, Debug)]
pub struct GroupFinding {
    /// Op ids of the group members (pairwise independent).
    pub ops: Vec<usize>,
    pub names: Vec<String>,
    pub algos: Vec<Algorithm>,
    /// Best-serial baseline (fastest algorithm each, back-to-back).
    pub serial_us: f64,
    /// Estimated co-run makespan with the discovered assignment.
    pub group_us: f64,
    pub combined_workspace: u64,
}

impl GroupFinding {
    pub fn speedup(&self) -> f64 {
        self.serial_us / self.group_us
    }

    pub fn width(&self) -> usize {
        self.ops.len()
    }
}

/// Scan a network for k-wide complementary convolution groups.
///
/// Candidate groups are the conv sets sharing one ASAP level — equal
/// levels guarantee pairwise independence (a dependency path strictly
/// increases the level), and they are exactly the fork branches
/// (inception modules, residual splits) whose co-execution the paper
/// studies. Each level set is handed to [`select_group`], heaviest conv
/// seeding, repeatedly: admitted members are removed and the remainder
/// re-scanned, so a wide level can yield several disjoint groups. Only
/// groups whose fluid-model speedup reaches `min_speedup` are kept.
/// (Cross-level independent combinations — which [`discover_pairs`]
/// does count pairwise — are out of scope here by construction.)
pub fn discover_groups(
    dag: &Dag,
    dev: &DeviceSpec,
    ws_budget: u64,
    k: usize,
    min_speedup: f64,
) -> Vec<GroupFinding> {
    let levels = dag.levels();
    let max_level = levels.iter().copied().max().unwrap_or(0);
    let mut findings = Vec::new();
    for level in 0..=max_level {
        let mut keyed: Vec<(usize, f64)> = dag
            .conv_ids()
            .into_iter()
            .filter(|&i| levels[i] == level)
            .map(|id| {
                let t = match &dag.ops[id].kind {
                    OpKind::Conv(p) => select_solo(
                        SelectionPolicy::FastestOnly,
                        p,
                        dev,
                        ws_budget,
                    )
                    .map(|d| isolated_time_us(&d, dev))
                    .unwrap_or(f64::INFINITY),
                    _ => unreachable!("conv_ids returned a non-conv"),
                };
                (id, t)
            })
            .collect();
        if keyed.len() < 2 {
            continue;
        }
        // heaviest first: the seed drives the group search
        keyed.sort_by(|a, b| {
            b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0))
        });
        let mut convs: Vec<usize> =
            keyed.into_iter().map(|(id, _)| id).collect();
        // peel groups off the level until nothing beneficial remains
        while convs.len() >= 2 {
            let params: Vec<&ConvParams> = convs
                .iter()
                .map(|&id| match &dag.ops[id].kind {
                    OpKind::Conv(p) => p,
                    _ => unreachable!(),
                })
                .collect();
            let Some(g) = select_group(&params, k, dev, ws_budget) else {
                break;
            };
            // the seed is always members[0] == 0 (select_group seeds
            // with candidates[0]); when its best group is too small or
            // too marginal, retire only the seed so its would-be
            // partners stay available for other combinations
            if g.members.len() < 2 || g.speedup() < min_speedup {
                convs.remove(0);
                continue;
            }
            let ops: Vec<usize> =
                g.members.iter().map(|&m| convs[m]).collect();
            let mut members = g.members.clone();
            members.sort_unstable();
            for &m in members.iter().rev() {
                convs.remove(m);
            }
            findings.push(GroupFinding {
                names: ops
                    .iter()
                    .map(|&i| dag.ops[i].name.to_string())
                    .collect(),
                algos: g.descs.iter().map(|d| d.algo).collect(),
                serial_us: g.serial_us,
                group_us: g.est_us,
                combined_workspace: g.combined_workspace(),
                ops,
            });
        }
    }
    findings.sort_by(|x, y| y.speedup().partial_cmp(&x.speedup()).unwrap());
    findings
}

fn best_serial_us(
    pa: &ConvParams,
    pb: &ConvParams,
    dev: &DeviceSpec,
    ws_budget: u64,
) -> f64 {
    let ta = select_solo(SelectionPolicy::FastestOnly, pa, dev, ws_budget)
        .map(|d| isolated_time_us(&d, dev))
        .unwrap_or(f64::INFINITY);
    let tb = select_solo(SelectionPolicy::FastestOnly, pb, dev, ws_budget)
        .map(|d| isolated_time_us(&d, dev))
        .unwrap_or(f64::INFINITY);
    ta + tb
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Network;

    const GB4: u64 = 4 * 1024 * 1024 * 1024;

    #[test]
    fn googlenet_has_at_least_27_cases() {
        // The paper's §2.1 count: "We discover 27 similar cases in this
        // network".
        let dag = Network::GoogleNet.build(32);
        let findings =
            discover_pairs(&dag, &DeviceSpec::k40(), GB4, 1.05);
        assert!(
            findings.len() >= 27,
            "only {} complementary pairs found",
            findings.len()
        );
    }

    #[test]
    fn resnet_has_instances_too() {
        // "... and more instances in other popular non-linear CNNs such as
        // ResNet."
        let dag = Network::ResNet50.build(32);
        let findings =
            discover_pairs(&dag, &DeviceSpec::k40(), GB4, 1.05);
        assert!(!findings.is_empty());
    }

    #[test]
    fn alexnet_has_none() {
        let dag = Network::AlexNet.build(32);
        let findings =
            discover_pairs(&dag, &DeviceSpec::k40(), GB4, 1.0);
        assert!(findings.is_empty());
    }

    #[test]
    fn findings_sorted_and_beneficial() {
        let dag = Network::GoogleNet.build(32);
        let findings =
            discover_pairs(&dag, &DeviceSpec::k40(), GB4, 1.05);
        for w in findings.windows(2) {
            assert!(w[0].speedup() >= w[1].speedup());
        }
        for f in &findings {
            assert!(f.speedup() >= 1.05);
            assert!(f.combined_workspace <= GB4);
            assert!(dag.independent(f.op_a, f.op_b));
        }
    }

    #[test]
    fn googlenet_has_group_opportunities() {
        // k-wide census: the inception branch sets must yield at least
        // one beneficial group, and every finding must be sound.
        let dag = Network::GoogleNet.build(32);
        let dev = DeviceSpec::k40();
        let findings = discover_groups(&dag, &dev, GB4, 4, 1.05);
        assert!(!findings.is_empty(), "no groups found in GoogleNet");
        for f in &findings {
            assert!(f.width() >= 2 && f.width() <= 4);
            assert!(f.speedup() >= 1.05);
            assert!(f.combined_workspace <= GB4);
            assert_eq!(f.names.len(), f.width());
            assert_eq!(f.algos.len(), f.width());
            for (i, &a) in f.ops.iter().enumerate() {
                for &b in f.ops.iter().skip(i + 1) {
                    assert!(
                        dag.independent(a, b),
                        "group members {a},{b} are dependent"
                    );
                }
            }
        }
        // sorted by speedup, like the pair census
        for w in findings.windows(2) {
            assert!(w[0].speedup() >= w[1].speedup());
        }
    }

    #[test]
    fn alexnet_has_no_groups() {
        let dag = Network::AlexNet.build(32);
        let findings =
            discover_groups(&dag, &DeviceSpec::k40(), GB4, 4, 1.0);
        assert!(findings.is_empty());
    }

    #[test]
    fn budget_shrinks_findings() {
        let dag = Network::GoogleNet.build(32);
        let dev = DeviceSpec::k40();
        let loose = discover_pairs(&dag, &dev, GB4, 1.05).len();
        let tight =
            discover_pairs(&dag, &dev, 8 * 1024 * 1024, 1.05).len();
        assert!(tight <= loose);
    }
}
