"""Hypothesis sweep: every algorithm ≡ oracle over random shapes/params.

The strategy draws (N, C, H, W, K, R, S, stride, padding) within each
algorithm's support envelope — exactly the cuDNN support matrix the paper's
Table 2 footnote alludes to (DIRECT/WINOGRAD unsupported for some inputs).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import kernels
from compile.kernels import ref

SETTINGS = dict(max_examples=20, deadline=None)


def run(algo, n, c, h, w, k, r, s, stride, pad):
    rng = np.random.default_rng(hash((n, c, h, w, k, r, s)) % 2**32)
    x = jnp.asarray(rng.standard_normal((n, c, h, w), dtype=np.float32))
    wt = jnp.asarray(rng.standard_normal((k, c, r, s), dtype=np.float32))
    got = kernels.dispatch(algo, x, wt, stride=stride, padding=pad)
    want = ref.conv2d_ref(x, wt, stride, pad)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=3e-4, atol=3e-4
    )


general = st.tuples(
    st.integers(1, 3),    # N
    st.integers(1, 6),    # C
    st.integers(5, 14),   # H
    st.integers(5, 14),   # W
    st.integers(1, 8),    # K
    st.integers(1, 4),    # R
    st.integers(1, 4),    # S
    st.integers(1, 2),    # stride
    st.integers(0, 2),    # pad
).filter(lambda t: t[2] + 2 * t[8] >= t[5] and t[3] + 2 * t[8] >= t[6])


@pytest.mark.parametrize(
    "algo", ["GEMM", "IMPLICIT_GEMM", "IMPLICIT_PRECOMP_GEMM", "DIRECT"]
)
@given(params=general)
@settings(**SETTINGS)
def test_general_algorithms(algo, params):
    n, c, h, w, k, r, s, stride, pad = params
    run(algo, n, c, h, w, k, r, s, (stride, stride), (pad, pad))


stride1 = st.tuples(
    st.integers(1, 2),    # N
    st.integers(1, 5),    # C
    st.integers(6, 16),   # H
    st.integers(6, 16),   # W
    st.integers(1, 6),    # K
    st.integers(1, 5),    # R
    st.integers(1, 5),    # S
    st.integers(0, 2),    # pad
).filter(lambda t: t[2] + 2 * t[7] >= t[4 + 1] and t[3] + 2 * t[7] >= t[6])


@pytest.mark.parametrize("algo", ["FFT", "FFT_TILING"])
@given(params=stride1)
@settings(**SETTINGS)
def test_fft_family(algo, params):
    n, c, h, w, k, r, s, pad = params
    run(algo, n, c, h, w, k, r, s, (1, 1), (pad, pad))


wino = st.tuples(
    st.integers(1, 2),    # N
    st.integers(1, 5),    # C
    st.integers(4, 16),   # H
    st.integers(4, 16),   # W
    st.integers(1, 6),    # K
    st.integers(0, 1),    # pad
).filter(lambda t: t[2] + 2 * t[5] >= 3 and t[3] + 2 * t[5] >= 3)


@given(params=wino)
@settings(**SETTINGS)
def test_winograd(params):
    n, c, h, w, k, pad = params
    run("WINOGRAD_NONFUSED", n, c, h, w, k, 3, 3, (1, 1), (pad, pad))
