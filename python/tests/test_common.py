"""Pallas building-block tests: matmul/bmm kernels + perf-structure estimates."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import common

RNG = np.random.default_rng(7)


def rand(shape):
    return jnp.asarray(RNG.standard_normal(shape, dtype=np.float32))


@pytest.mark.parametrize(
    "m,k,n",
    [(4, 3, 5), (128, 64, 128), (130, 17, 250), (1, 1, 1), (256, 300, 64)],
)
def test_matmul_matches_jnp(m, k, n):
    a, b = rand((m, k)), rand((k, n))
    got = common.matmul(a, b)
    want = a @ b
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4
    )


def test_matmul_custom_tiles():
    a, b = rand((100, 40)), rand((40, 90))
    got = common.matmul(a, b, bm=32, bn=64)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(a @ b), rtol=2e-4, atol=2e-4
    )


@pytest.mark.parametrize("t,m,k,n", [(1, 4, 5, 6), (16, 8, 3, 12)])
def test_bmm_matches_einsum(t, m, k, n):
    a, b = rand((t, m, k)), rand((t, k, n))
    got = common.bmm(a, b)
    want = jnp.einsum("bmk,bkn->bmn", a, b)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4
    )


def test_vmem_estimate_within_budget():
    # Perf-structure invariant (EXPERIMENTS.md §Perf): every matmul shape
    # used by the conv kernels in this project fits the 16 MB VMEM budget.
    VMEM = 16 * 1024 * 1024
    # largest project shape: train_step stem GEMM on batch 16:
    # (16, C*R*S=27) x (27, 16*32*32)
    assert common.estimate_matmul_vmem(16, 27, 16 * 32 * 32) < VMEM
    # inception 3x3 at paper scale (32, 96, 28, 28) -> (128, 864) x (864, 25088)
    assert common.estimate_matmul_vmem(128, 864, 25088) < VMEM


def test_mxu_utilization_bounds():
    u = common.estimate_mxu_utilization(128, 64, 128)
    assert u == 1.0
    u2 = common.estimate_mxu_utilization(129, 64, 129)
    assert 0.2 < u2 < 1.0
    assert common.estimate_mxu_utilization(0, 1, 1) == 0.0


def test_matmul_preserves_dtype():
    a = rand((8, 8)).astype(jnp.bfloat16)
    b = rand((8, 8)).astype(jnp.bfloat16)
    out = common.matmul(a, b)
    assert out.dtype == jnp.bfloat16
