"""AOT path tests: HLO-text lowering and manifest format."""

import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot, model


def test_to_hlo_text_roundtrips_simple_fn():
    def fn(x, y):
        return (jnp.matmul(x, y) + 2.0,)

    spec = jax.ShapeDtypeStruct((2, 2), jnp.float32)
    text = aot.to_hlo_text(jax.jit(fn).lower(spec, spec))
    assert "HloModule" in text
    assert "dot" in text


def test_to_hlo_text_contains_pallas_lowering():
    from compile.kernels import conv2d_direct

    xs = jax.ShapeDtypeStruct((1, 2, 6, 6), jnp.float32)
    ws = jax.ShapeDtypeStruct((2, 2, 3, 3), jnp.float32)
    fn = lambda x, w: (conv2d_direct(x, w, padding=(1, 1)),)
    text = aot.to_hlo_text(jax.jit(fn).lower(xs, ws))
    # interpret-mode pallas lowers to plain HLO (while/dynamic-slice loops),
    # never a custom-call the CPU client can't run.
    assert "HloModule" in text
    assert "custom-call" not in text.lower() or "Mosaic" not in text


def test_manifest_format():
    m = aot.Manifest()
    m.add(
        "demo",
        "demo.hlo.txt",
        [jax.ShapeDtypeStruct((2, 3), jnp.float32)],
        [jax.ShapeDtypeStruct((2,), jnp.int32)],
    )
    joined = "\n".join(m.lines)
    assert "artifact demo" in joined
    assert "input float32 2x3" in joined
    assert "output int32 2" in joined


def test_scalar_shape_formatting():
    assert aot._fmt_shape(()) == "scalar"
    assert aot._fmt_shape((4, 5)) == "4x5"


@pytest.mark.skipif(
    not os.path.exists(os.path.join(os.path.dirname(__file__), "..", "..",
                                    "artifacts", "manifest.txt")),
    reason="artifacts not built",
)
def test_emitted_manifest_lists_all_artifacts():
    root = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    with open(os.path.join(root, "manifest.txt")) as f:
        text = f.read()
    names = [
        line.split()[1] for line in text.splitlines()
        if line.startswith("artifact ")
    ]
    # 7 algos on c3 + 6 on c5 + 3 model artifacts
    assert len(names) == 16
    assert "train_step" in names and "model_fwd" in names
    for n in names:
        fname = os.path.join(root, f"{n}.hlo.txt")
        assert os.path.exists(fname), fname
        with open(fname) as f:
            assert "HloModule" in f.read(200)


def test_train_step_abi_matches_manifest():
    # 30 inputs = x, y, 28 params; 29 outputs = 28 params + loss.
    assert len(model.param_spec()) == 28
