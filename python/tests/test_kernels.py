"""Kernel vs oracle: the core correctness signal for Layer 1.

Every cuDNN-style algorithm implementation must agree with the XLA
convolution oracle (and the oracle with the loop-nest oracle) across
shapes, strides, paddings, and dtypes.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import kernels
from compile.kernels import fft_conv, im2col_gemm, implicit_gemm, ref, winograd

RNG = np.random.default_rng(1234)


def rand(shape, dtype=np.float32):
    return jnp.asarray(RNG.standard_normal(shape, dtype=np.float32)).astype(
        dtype
    )


def check(algo, xs, ws, stride=(1, 1), padding=(0, 0), tol=2e-4):
    x, w = rand(xs), rand(ws)
    got = kernels.dispatch(algo, x, w, stride=stride, padding=padding)
    want = ref.conv2d_ref(x, w, stride, padding)
    assert got.shape == want.shape
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=tol, atol=tol
    )


ALL_ALGOS = sorted(kernels.ALGORITHMS)
STRIDE1_ALGOS = ALL_ALGOS
GENERAL_ALGOS = ["GEMM", "IMPLICIT_GEMM", "IMPLICIT_PRECOMP_GEMM", "DIRECT"]


# ---------------------------------------------------------------------------
# basic agreement
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("algo", ALL_ALGOS)
def test_3x3_pad1(algo):
    check(algo, (2, 3, 14, 14), (8, 3, 3, 3), padding=(1, 1))


@pytest.mark.parametrize(
    "algo", [a for a in ALL_ALGOS if a != "WINOGRAD_NONFUSED"]
)
def test_5x5_pad2(algo):
    check(algo, (2, 4, 12, 12), (6, 4, 5, 5), padding=(2, 2))


@pytest.mark.parametrize("algo", ALL_ALGOS)
def test_1x1_like_inception_reduce(algo):
    if algo == "WINOGRAD_NONFUSED":
        pytest.skip("winograd is 3x3-only")
    check(algo, (2, 16, 8, 8), (4, 16, 1, 1))


@pytest.mark.parametrize("algo", GENERAL_ALGOS)
def test_stride2(algo):
    check(algo, (2, 3, 15, 15), (5, 3, 3, 3), stride=(2, 2), padding=(1, 1))


@pytest.mark.parametrize("algo", GENERAL_ALGOS)
def test_asymmetric_stride_pad(algo):
    check(algo, (1, 2, 13, 9), (3, 2, 3, 2), stride=(2, 1), padding=(1, 0))


@pytest.mark.parametrize("algo", ALL_ALGOS)
def test_single_pixel_output(algo):
    if algo == "WINOGRAD_NONFUSED":
        check(algo, (1, 2, 3, 3), (2, 2, 3, 3))
    else:
        check(algo, (1, 2, 5, 5), (2, 2, 5, 5))


@pytest.mark.parametrize("algo", STRIDE1_ALGOS)
def test_rectangular_input(algo):
    r = 3 if algo == "WINOGRAD_NONFUSED" else 2
    check(algo, (2, 3, 10, 17), (4, 3, r, r), padding=(1, 1))


def test_batch_one_and_many():
    for n in (1, 5):
        check("DIRECT", (n, 3, 9, 9), (7, 3, 3, 3), padding=(1, 1))


def test_many_channels_direct_tiling():
    # K > bk tile so the output-channel grid dimension is exercised.
    check("DIRECT", (1, 4, 8, 8), (70, 4, 3, 3), padding=(1, 1))


# ---------------------------------------------------------------------------
# oracle self-consistency
# ---------------------------------------------------------------------------


def test_oracle_vs_loops():
    x, w = rand((2, 3, 8, 8)), rand((4, 3, 3, 3))
    a = ref.conv2d_ref(x, w, (1, 1), (1, 1))
    b = ref.conv2d_loops(x, w, (1, 1), (1, 1))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-5)


def test_oracle_vs_loops_strided():
    x, w = rand((1, 2, 9, 9)), rand((3, 2, 3, 3))
    a = ref.conv2d_ref(x, w, (2, 2), (1, 1))
    b = ref.conv2d_loops(x, w, (2, 2), (1, 1))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-5)


def test_im2col_matches_gemm_identity():
    # conv with identity-like filter == patch extraction
    x = rand((1, 2, 6, 6))
    cols = ref.im2col(x, 3, 3, (1, 1), (0, 0))
    assert cols.shape == (1, 2 * 9, 16)


# ---------------------------------------------------------------------------
# NOT_SUPPORTED semantics (cuDNN status-code mirror)
# ---------------------------------------------------------------------------


def test_winograd_rejects_5x5():
    x, w = rand((1, 2, 8, 8)), rand((2, 2, 5, 5))
    with pytest.raises(winograd.NotSupported):
        kernels.conv2d_winograd(x, w)


def test_winograd_rejects_stride2():
    x, w = rand((1, 2, 8, 8)), rand((2, 2, 3, 3))
    with pytest.raises(winograd.NotSupported):
        kernels.conv2d_winograd(x, w, stride=(2, 2))


def test_fft_rejects_stride2():
    x, w = rand((1, 2, 8, 8)), rand((2, 2, 3, 3))
    with pytest.raises(fft_conv.NotSupported):
        kernels.conv2d_fft(x, w, stride=(2, 2))
    with pytest.raises(fft_conv.NotSupported):
        kernels.conv2d_fft_tiling(x, w, stride=(2, 2))


def test_dispatch_unknown_algo():
    x, w = rand((1, 2, 8, 8)), rand((2, 2, 3, 3))
    with pytest.raises(KeyError):
        kernels.dispatch("NOT_AN_ALGO", x, w)


# ---------------------------------------------------------------------------
# workspace model sanity (Table 2 semantics)
# ---------------------------------------------------------------------------


def test_gemm_workspace_is_im2col_size():
    xs, ws = (2, 3, 14, 14), (8, 3, 3, 3)
    b = im2col_gemm.workspace_bytes(xs, ws, padding=(1, 1))
    assert b == 2 * 3 * 9 * 14 * 14 * 4


def test_precomp_workspace_small_vs_gemm():
    xs, ws = (32, 96, 28, 28), (128, 96, 3, 3)
    small = implicit_gemm.precomp_workspace_bytes(xs, ws, padding=(1, 1))
    big = im2col_gemm.workspace_bytes(xs, ws, padding=(1, 1))
    assert small < big / 10


def test_fft_tiling_workspace_below_fft():
    # Table 2 shape relation: FFT_TILING uses roughly half of FFT. Holds
    # once the image spans multiple 32x32 tiles (for single-tile images the
    # halo makes tiling pointless, as in cuDNN).
    xs, ws = (32, 16, 64, 64), (48, 16, 5, 5)
    full = fft_conv.workspace_bytes_fft(xs, ws, padding=(2, 2))
    tiled = fft_conv.workspace_bytes_fft_tiling(xs, ws, padding=(2, 2))
    assert tiled < full


def test_fft_tiling_large_filter_rejected():
    x, w = rand((1, 2, 64, 64)), rand((2, 2, 33, 33))
    with pytest.raises(fft_conv.NotSupported):
        kernels.conv2d_fft_tiling(x, w)


# ---------------------------------------------------------------------------
# dtype coverage
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("algo", ["DIRECT", "IMPLICIT_GEMM", "GEMM"])
def test_bfloat16(algo):
    x = rand((1, 3, 8, 8), jnp.bfloat16)
    w = rand((4, 3, 3, 3), jnp.bfloat16)
    got = kernels.dispatch(algo, x, w, padding=(1, 1))
    want = ref.conv2d_ref(x, w, (1, 1), (1, 1))
    np.testing.assert_allclose(
        np.asarray(got, np.float32),
        np.asarray(want, np.float32),
        rtol=5e-2,
        atol=5e-2,
    )
