"""Layer-2 model tests: shapes, ABI stability, gradient flow, learnability."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model


@pytest.fixture(scope="module")
def params():
    return model.init_params(0)


def test_param_spec_matches_init(params):
    spec = model.param_spec()
    assert len(spec) == len(params)
    for (name, shape), p in zip(spec, params):
        assert tuple(p.shape) == shape, name


def test_param_spec_is_stable_abi():
    # The Rust runtime passes buffers positionally; the order must never
    # silently change. Pin the first/last entries and the count.
    spec = model.param_spec()
    assert spec[0][0] == "stem_w"
    assert spec[-1][0] == "fc_b"
    assert len(spec) == 28


def test_forward_shape(params):
    x, _ = model.make_batch(0, batch=4)
    logits = model.forward(params, x)
    assert logits.shape == (4, model.NUM_CLASSES)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_inception_concat_channels(params):
    p = {n: v for (n, _), v in zip(model.param_spec(), params)}
    x = jnp.ones((2, 16, 16, 16), jnp.float32)
    y = model.inception(p, "ia", x, model.DEFAULT_ALGOS)
    # 8 + 16 + 8 + 8 branch outputs
    assert y.shape == (2, 40, 16, 16)


def test_loss_finite_positive(params):
    x, y = model.make_batch(1)
    loss = model.loss_fn(params, x, y)
    assert bool(jnp.isfinite(loss)) and float(loss) > 0


def test_gradients_nonzero_everywhere(params):
    x, y = model.make_batch(2)
    grads = jax.grad(model.loss_fn)(params, x, y)
    names = [n for n, _ in model.param_spec()]
    for name, g in zip(names, grads):
        assert bool(jnp.all(jnp.isfinite(g))), name
        assert float(jnp.max(jnp.abs(g))) > 0, f"dead gradient: {name}"


def test_train_step_abi(params):
    x, y = model.make_batch(0)
    out = model.train_step(params, x, y)
    assert len(out) == len(params) + 1
    assert out[-1].shape == ()


def test_loss_descends_30_steps(params):
    p = list(params)
    first = None
    for step in range(30):
        x, y = model.make_batch(step % 8)
        out = model.train_step(p, x, y, lr=0.01)
        p = list(out[:-1])
        if first is None:
            first = float(out[-1])
    last = float(out[-1])
    assert last < first * 0.7, (first, last)


def test_algo_choice_does_not_change_numerics(params):
    # The paper's premise: algorithm selection is a performance/memory knob,
    # never a numerics knob.
    x, _ = model.make_batch(3, batch=2)
    base = model.forward(params, x, model.DEFAULT_ALGOS)
    alt = dict(model.DEFAULT_ALGOS, b3="DIRECT", b5="GEMM", stem="GEMM")
    other = model.forward(params, x, alt)
    np.testing.assert_allclose(
        np.asarray(base), np.asarray(other), rtol=2e-3, atol=2e-3
    )


def test_make_batch_deterministic():
    x1, y1 = model.make_batch(7)
    x2, y2 = model.make_batch(7)
    assert np.array_equal(np.asarray(x1), np.asarray(x2))
    assert np.array_equal(np.asarray(y1), np.asarray(y2))


def test_make_batch_class_balance_ish():
    ys = np.concatenate(
        [np.asarray(model.make_batch(s, 64)[1]) for s in range(4)]
    )
    assert len(np.unique(ys)) == model.NUM_CLASSES
