"""Pytest wiring for the kernel suite.

- Puts this directory on ``sys.path`` so ``from compile import ...``
  resolves no matter where pytest is invoked from.
- Keeps *collection* green when parts of the toolchain are absent or
  broken (the CI python lane is allowed-to-fail on execution, but must
  always collect): test modules import ``jax``/``hypothesis`` at module
  scope, so modules whose imports would fail are dropped from
  collection instead of erroring. A real import probe (not
  ``find_spec``) is used so a broken wheel counts as missing.
"""

import importlib
import os
import sys

sys.path.insert(0, os.path.dirname(__file__))


def _importable(module):
    try:
        importlib.import_module(module)
        return True
    except Exception:
        return False


collect_ignore_glob = []
if not (_importable("jax") and _importable("numpy")):
    # every test module needs the JAX/Pallas stack
    collect_ignore_glob = ["tests/*"]
elif not _importable("hypothesis"):
    collect_ignore_glob = ["tests/test_hypothesis_*"]
