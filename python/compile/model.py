"""Layer-2: mini-GoogleNet (stem + 2 inception modules) in JAX.

This is the paper's workload class: a *non-linear* network whose inception
modules contain four independent branches (Figure 1 right). Every forward
convolution goes through :func:`conv2d`, which dispatches to one of the
seven Layer-1 algorithm implementations — the same per-op algorithm choice
the paper studies — so the lowered HLO genuinely contains the Pallas
kernels of the selected algorithms.

Backward: cuDNN picks *separate* algorithms for bwd-data/bwd-filter; we
model that by giving :func:`conv2d` a custom VJP whose backward is XLA's
native convolution transpose (exact gradients, independent of the forward
algorithm choice — mirroring that fwd algo selection never changes
numerics).

Everything here is build-time only: ``aot.py`` lowers ``train_step`` /
``forward`` once to HLO text and the Rust coordinator drives the artifacts.
"""

from __future__ import annotations

import functools
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from . import kernels
from .kernels import ref

# ---------------------------------------------------------------------------
# conv2d with algorithm dispatch + exact custom VJP
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def conv2d(x, w, stride, padding, algo):
    """Forward convolution through the chosen cuDNN-style algorithm."""
    return kernels.dispatch(algo, x, w, stride=stride, padding=padding)


def _conv2d_fwd(x, w, stride, padding, algo):
    return conv2d(x, w, stride, padding, algo), (x, w)


def _conv2d_bwd(stride, padding, algo, res, dy):
    x, w = res
    _, vjp = jax.vjp(
        lambda xx, ww: ref.conv2d_ref(xx, ww, stride, padding), x, w
    )
    return vjp(dy)


conv2d.defvjp(_conv2d_fwd, _conv2d_bwd)


# ---------------------------------------------------------------------------
# Parameter handling: a stable, ordered flat list so the Rust runtime can
# pass buffers positionally.
# ---------------------------------------------------------------------------

# Default per-op algorithm assignment. 1x1 convs are GEMM-shaped already
# (implicit GEMM); 3x3 favors Winograd; 5x5 favors the FFT family — matching
# the sweet spots the paper's Table 2 exhibits.
DEFAULT_ALGOS: Dict[str, str] = {
    "stem": "IMPLICIT_GEMM",
    "b1": "IMPLICIT_PRECOMP_GEMM",
    "b3r": "IMPLICIT_PRECOMP_GEMM",
    "b3": "WINOGRAD_NONFUSED",
    "b5r": "IMPLICIT_PRECOMP_GEMM",
    "b5": "FFT_TILING",
    "bp": "IMPLICIT_PRECOMP_GEMM",
}

# (name, K, C, R, S) per conv; inception channel plans keep the model tiny
# (~25k params) so a few hundred CPU training steps run in seconds.
STEM = ("stem", 16, 3, 3, 3)
INCEPTION_A = {  # on 16 channels -> 40 out
    "b1": (8, 16, 1, 1),
    "b3r": (8, 16, 1, 1),
    "b3": (16, 8, 3, 3),
    "b5r": (4, 16, 1, 1),
    "b5": (8, 4, 5, 5),
    "bp": (8, 16, 1, 1),
}
INCEPTION_B = {  # on 40 channels -> 64 out
    "b1": (16, 40, 1, 1),
    "b3r": (12, 40, 1, 1),
    "b3": (24, 12, 3, 3),
    "b5r": (6, 40, 1, 1),
    "b5": (12, 6, 5, 5),
    "bp": (12, 40, 1, 1),
}
NUM_CLASSES = 8
IMAGE_SHAPE = (3, 32, 32)

_BRANCH_ORDER = ["b1", "b3r", "b3", "b5r", "b5", "bp"]


def param_spec() -> List[Tuple[str, Tuple[int, ...]]]:
    """Ordered (name, shape) list — the positional ABI of the artifacts."""
    spec: List[Tuple[str, Tuple[int, ...]]] = []
    name, k, c, r, s = STEM
    spec.append((f"{name}_w", (k, c, r, s)))
    spec.append((f"{name}_b", (k,)))
    for tag, plan in (("ia", INCEPTION_A), ("ib", INCEPTION_B)):
        for br in _BRANCH_ORDER:
            k, c, r, s = plan[br]
            spec.append((f"{tag}_{br}_w", (k, c, r, s)))
            spec.append((f"{tag}_{br}_b", (k,)))
    spec.append(("fc_w", (64, NUM_CLASSES)))
    spec.append(("fc_b", (NUM_CLASSES,)))
    return spec


def init_params(seed: int = 0) -> List[jnp.ndarray]:
    """He-initialized parameters in param_spec order."""
    rng = np.random.default_rng(seed)
    params = []
    for name, shape in param_spec():
        if name.endswith("_b"):
            params.append(jnp.zeros(shape, jnp.float32))
        else:
            fan_in = int(np.prod(shape[1:])) if len(shape) > 1 else shape[0]
            std = float(np.sqrt(2.0 / fan_in))
            params.append(
                jnp.asarray(
                    rng.standard_normal(shape, dtype=np.float32) * std
                )
            )
    return params


def _unflatten(params: List[jnp.ndarray]) -> Dict[str, jnp.ndarray]:
    return {name: p for (name, _), p in zip(param_spec(), params)}


# ---------------------------------------------------------------------------
# Network
# ---------------------------------------------------------------------------


def _maxpool(x, window: int, stride: int, pad: int):
    return lax.reduce_window(
        x,
        -jnp.inf,
        lax.max,
        (1, 1, window, window),
        (1, 1, stride, stride),
        [(0, 0), (0, 0), (pad, pad), (pad, pad)],
    )


def _conv_bias_relu(x, w, b, stride, padding, algo):
    y = conv2d(x, w, stride, padding, algo)
    return jax.nn.relu(y + b[None, :, None, None])


def inception(p: Dict[str, jnp.ndarray], tag: str, x, algos: Dict[str, str]):
    """One inception module: four independent branches, channel concat.

    The four branches are the paper's "independent paths of chained
    operations" — the inter-op parallelism the Rust coordinator schedules.
    """
    g = lambda n: (p[f"{tag}_{n}_w"], p[f"{tag}_{n}_b"])
    b1 = _conv_bias_relu(x, *g("b1"), (1, 1), (0, 0), algos["b1"])
    t = _conv_bias_relu(x, *g("b3r"), (1, 1), (0, 0), algos["b3r"])
    b3 = _conv_bias_relu(t, *g("b3"), (1, 1), (1, 1), algos["b3"])
    t = _conv_bias_relu(x, *g("b5r"), (1, 1), (0, 0), algos["b5r"])
    b5 = _conv_bias_relu(t, *g("b5"), (1, 1), (2, 2), algos["b5"])
    t = _maxpool(x, 3, 1, 1)
    bp = _conv_bias_relu(t, *g("bp"), (1, 1), (0, 0), algos["bp"])
    return jnp.concatenate([b1, b3, b5, bp], axis=1)


def forward(params: List[jnp.ndarray], x, algos: Dict[str, str] = None):
    """Logits for a batch of NCHW images."""
    algos = algos or DEFAULT_ALGOS
    p = _unflatten(params)
    h = _conv_bias_relu(x, p["stem_w"], p["stem_b"], (1, 1), (1, 1),
                        algos["stem"])
    h = _maxpool(h, 2, 2, 0)  # 32 -> 16
    h = inception(p, "ia", h, algos)
    h = _maxpool(h, 2, 2, 0)  # 16 -> 8
    h = inception(p, "ib", h, algos)
    h = jnp.mean(h, axis=(2, 3))  # global average pool -> (N, 64)
    return h @ p["fc_w"] + p["fc_b"]


def loss_fn(params: List[jnp.ndarray], x, y, algos=None):
    """Mean softmax cross-entropy; y is int32 class ids."""
    logits = forward(params, x, algos)
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))


def train_step(params: List[jnp.ndarray], x, y, lr: float = 0.01):
    """One SGD step. Returns (new_params..., loss) — the AOT artifact ABI."""
    loss, grads = jax.value_and_grad(loss_fn)(params, x, y)
    new_params = [p - lr * g for p, g in zip(params, grads)]
    return tuple(new_params) + (loss,)


def make_batch(seed: int, batch: int = 16):
    """Synthetic 8-class task: class-dependent frequency patterns + noise.

    Learnable but not trivial — the loss curve in examples/train_cnn.rs must
    actually descend.
    """
    rng = np.random.default_rng(seed)
    y = rng.integers(0, NUM_CLASSES, size=batch).astype(np.int32)
    c, h, w = IMAGE_SHAPE
    ii, jj = np.meshgrid(np.arange(h), np.arange(w), indexing="ij")
    x = np.empty((batch, c, h, w), dtype=np.float32)
    for b in range(batch):
        freq = 1 + y[b]
        base = np.sin(2 * np.pi * freq * ii / h) * np.cos(
            2 * np.pi * freq * jj / w
        )
        x[b] = base[None] + 0.3 * rng.standard_normal((c, h, w))
    return jnp.asarray(x), jnp.asarray(y)
