"""Layer-1 kernels: the seven cuDNN forward-convolution algorithms.

Each algorithm family is implemented as a real computation (Pallas where the
inner loop is MXU-shaped, jnp where it is not) and validated against
``ref.conv2d_ref``. ``ALGORITHMS`` maps the cuDNN enum names used throughout
the paper (Tables 1-2) to the implementations; ``dispatch`` mirrors
``cudnnConvolutionForward`` with an explicit algo argument.
"""

from __future__ import annotations

from . import ref
from .direct import conv2d_direct
from .fft_conv import (
    NotSupported as FftNotSupported,
    conv2d_fft,
    conv2d_fft_tiling,
)
from .im2col_gemm import conv2d_gemm
from .implicit_gemm import conv2d_implicit_gemm, conv2d_precomp_gemm
from .winograd import NotSupported as WinogradNotSupported, conv2d_winograd

ALGORITHMS = {
    "GEMM": conv2d_gemm,
    "IMPLICIT_GEMM": conv2d_implicit_gemm,
    "IMPLICIT_PRECOMP_GEMM": conv2d_precomp_gemm,
    "WINOGRAD_NONFUSED": conv2d_winograd,
    "DIRECT": conv2d_direct,
    "FFT": conv2d_fft,
    "FFT_TILING": conv2d_fft_tiling,
}


def dispatch(algo: str, x, w, stride=(1, 1), padding=(0, 0)):
    """Run one forward convolution with an explicitly chosen algorithm.

    Raises KeyError for unknown algorithms and the algorithm's NotSupported
    for configurations it cannot handle (mirroring cuDNN's status codes).
    """
    return ALGORITHMS[algo](x, w, stride=stride, padding=padding)


__all__ = [
    "ALGORITHMS",
    "dispatch",
    "ref",
    "conv2d_direct",
    "conv2d_gemm",
    "conv2d_implicit_gemm",
    "conv2d_precomp_gemm",
    "conv2d_winograd",
    "conv2d_fft",
    "conv2d_fft_tiling",
    "FftNotSupported",
    "WinogradNotSupported",
]
