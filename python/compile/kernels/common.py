"""Shared Pallas building blocks: tiled matmul and batched matmul.

These are the MXU-shaped inner loops every GEMM-family convolution algorithm
reduces to (DESIGN.md §Hardware-Adaptation): on TPU the natural form of
im2col-GEMM / implicit-GEMM / Winograd is a matmul tile that fits VMEM and
feeds the 128x128 systolic array. BlockSpec expresses the HBM->VMEM schedule
that the cuDNN kernels express with threadblocks.

All kernels are lowered with ``interpret=True``: real-TPU lowering emits a
Mosaic custom-call the CPU PJRT plugin cannot execute (see
/opt/xla-example/README.md), while interpret mode lowers to plain HLO that
runs anywhere — the numerics are identical.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-friendly default tiles. 128 matches the MXU systolic array edge; the
# M tile is kept small so (bm, K) + (K, bn) + (bm, bn) stays well under the
# ~16 MB VMEM budget for every shape used in this project (checked in
# estimate_matmul_vmem / tests).
DEFAULT_BM = 128
DEFAULT_BN = 128


def _pad_to(x, axis: int, multiple: int):
    size = x.shape[axis]
    rem = (-size) % multiple
    if rem == 0:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, rem)
    return jnp.pad(x, pads)


def _matmul_kernel(a_ref, b_ref, o_ref):
    # One (bm, K) x (K, bn) tile product per grid step. f32 accumulate on
    # the MXU; preferred_element_type pins the accumulator width.
    o_ref[...] = jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def matmul(a, b, bm: int = DEFAULT_BM, bn: int = DEFAULT_BN):
    """C = A @ B with a Pallas kernel, grid over (M/bm, N/bn) output tiles.

    The contraction dim K is kept whole per tile: for every convolution in
    this project K = C*R*S (or C) is at most a few thousand, so the A-panel
    fits VMEM comfortably and no K-loop / revisiting is needed.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"matmul inner dims {k} != {k2}"
    ap = _pad_to(a, 0, bm)
    bp = _pad_to(b, 1, bn)
    mp, np_ = ap.shape[0], bp.shape[1]
    out = pl.pallas_call(
        _matmul_kernel,
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), a.dtype),
        interpret=True,
    )(ap, bp)
    return out[:m, :n]


def _bmm_kernel(a_ref, b_ref, o_ref):
    # Full per-batch matrices: (1, M, K) x (1, K, N). Each Winograd frequency
    # position / FFT tile is one batch element.
    o_ref[...] = jnp.einsum(
        "bmk,bkn->bmn",
        a_ref[...],
        b_ref[...],
        preferred_element_type=jnp.float32,
    ).astype(o_ref.dtype)


@jax.jit
def bmm(a, b):
    """Batched matmul C[t] = A[t] @ B[t] with grid over the batch dim.

    Used by the Winograd kernel: the 16 frequency positions of F(2x2, 3x3)
    are independent (K, C) x (C, P) products.
    """
    t, m, k = a.shape
    t2, k2, n = b.shape
    assert t == t2 and k == k2
    return pl.pallas_call(
        _bmm_kernel,
        grid=(t,),
        in_specs=[
            pl.BlockSpec((1, m, k), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, k, n), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, m, n), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((t, m, n), a.dtype),
        interpret=True,
    )(a, b)


def estimate_matmul_vmem(m: int, k: int, n: int, bm: int = DEFAULT_BM,
                         bn: int = DEFAULT_BN, bytes_per_el: int = 4) -> int:
    """VMEM bytes resident per grid step of :func:`matmul`.

    Structural perf metric recorded in EXPERIMENTS.md §Perf (interpret-mode
    wallclock is CPU-numpy time, not a TPU proxy).
    """
    return (bm * k + k * bn + bm * bn) * bytes_per_el


def estimate_mxu_utilization(m: int, k: int, n: int, bm: int = DEFAULT_BM,
                             bn: int = DEFAULT_BN) -> float:
    """Fraction of MXU-issued MACs that are useful (non-padding) work."""
    mp = ((m + bm - 1) // bm) * bm
    np_ = ((n + bn - 1) // bn) * bn
    issued = mp * k * np_
    useful = m * k * n
    return useful / issued if issued else 0.0
