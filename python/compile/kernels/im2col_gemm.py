"""GEMM convolution (cuDNN CUDNN_CONVOLUTION_FWD_ALGO_GEMM).

The classic explicit-workspace algorithm: materialize the im2col matrix in
device memory (this allocation IS the "workspace memory" column of the
paper's Table 2), then one big GEMM through the shared Pallas matmul tile
kernel. Workspace bytes = N * C*R*S * Ho*Wo * sizeof(dtype) — the largest of
the GEMM family, which is why TensorFlow's fastest-only selection can blow
the memory budget (paper §2.1 "Device Memory").
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .common import matmul


@functools.partial(jax.jit, static_argnames=("stride", "padding"))
def conv2d_gemm(x, w, stride=(1, 1), padding=(0, 0)):
    """Explicit im2col + GEMM convolution. Any stride/padding."""
    n, c, h, wd = x.shape
    k, _, r, s = w.shape
    ho, wo = ref.out_dims(h, wd, r, s, stride, padding)
    # Workspace: (N, C*R*S, Ho*Wo) materialized in device memory.
    cols = ref.im2col(x, r, s, stride, padding)
    # Fold batch into the GEMM's N dim: (C*R*S, N*Ho*Wo).
    cols2 = jnp.transpose(cols, (1, 0, 2)).reshape(c * r * s, n * ho * wo)
    wmat = w.reshape(k, c * r * s)
    y = matmul(wmat, cols2)  # (K, N*Ho*Wo)
    y = y.reshape(k, n, ho, wo)
    return jnp.transpose(y, (1, 0, 2, 3))


def workspace_bytes(x_shape, w_shape, stride=(1, 1), padding=(0, 0),
                    bytes_per_el: int = 4) -> int:
    """Device-memory workspace this algorithm allocates (Table 2 column)."""
    n, c, h, wd = x_shape
    k, _, r, s = w_shape
    ho, wo = ref.out_dims(h, wd, r, s, stride, padding)
    return n * c * r * s * ho * wo * bytes_per_el
