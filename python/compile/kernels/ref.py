"""Pure-jnp correctness oracle for every convolution kernel in this package.

All Layer-1 Pallas kernels (direct, im2col-GEMM, implicit-GEMM, Winograd,
FFT) must agree with :func:`conv2d_ref` to float32 tolerance. This mirrors
cuDNN's contract: seven algorithms, one mathematical convolution.

Layout convention (used throughout the project):
  input  x : (N, C, H, W)        NCHW
  filter w : (K, C, R, S)        OIHW (cross-correlation, like cuDNN)
  output y : (N, K, Ho, Wo)
  Ho = (H + 2*pad_h - R) // stride_h + 1, similarly Wo.
"""

from __future__ import annotations

import jax.numpy as jnp
from jax import lax


def out_dims(h: int, w: int, r: int, s: int, stride=(1, 1), padding=(0, 0)):
    """Output spatial dims for a convolution (matches cuDNN formula)."""
    ho = (h + 2 * padding[0] - r) // stride[0] + 1
    wo = (w + 2 * padding[1] - s) // stride[1] + 1
    return ho, wo


def conv2d_ref(x, w, stride=(1, 1), padding=(0, 0)):
    """Reference 2-D cross-correlation via lax.conv_general_dilated.

    This is the oracle: XLA's own convolution, independent of every kernel
    implementation in this package.
    """
    return lax.conv_general_dilated(
        x,
        w,
        window_strides=stride,
        padding=[(padding[0], padding[0]), (padding[1], padding[1])],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )


def conv2d_loops(x, w, stride=(1, 1), padding=(0, 0)):
    """Second, structurally independent oracle: explicit patch extraction.

    Slower but trivially auditable; used in tests to cross-check the oracle
    itself on small shapes.
    """
    n, c, h, wd = x.shape
    k, _, r, s = w.shape
    ho, wo = out_dims(h, wd, r, s, stride, padding)
    xp = jnp.pad(
        x, ((0, 0), (0, 0), (padding[0], padding[0]), (padding[1], padding[1]))
    )
    rows = []
    for i in range(ho):
        cols = []
        for j in range(wo):
            patch = xp[
                :,
                :,
                i * stride[0] : i * stride[0] + r,
                j * stride[1] : j * stride[1] + s,
            ]
            # (N, C, R, S) x (K, C, R, S) -> (N, K)
            cols.append(jnp.einsum("ncrs,kcrs->nk", patch, w))
        rows.append(jnp.stack(cols, axis=-1))  # (N, K, Wo)
    return jnp.stack(rows, axis=-2)  # (N, K, Ho, Wo)


def im2col(x, r: int, s: int, stride=(1, 1), padding=(0, 0)):
    """Materialize the im2col workspace matrix.

    Returns (N, C*R*S, Ho*Wo) — this is exactly the "workspace memory" the
    GEMM-family cuDNN algorithms allocate (paper §2, Table 2).
    """
    n, c, h, wd = x.shape
    ho, wo = out_dims(h, wd, r, s, stride, padding)
    xp = jnp.pad(
        x, ((0, 0), (0, 0), (padding[0], padding[0]), (padding[1], padding[1]))
    )
    cols = []
    for dr in range(r):
        for ds in range(s):
            patch = xp[
                :,
                :,
                dr : dr + ho * stride[0] : stride[0],
                ds : ds + wo * stride[1] : stride[1],
            ]
            cols.append(patch.reshape(n, c, ho * wo))
    # Stack as (N, C, R*S, Ho*Wo) -> (N, C*R*S, Ho*Wo), C-major to match
    # w.reshape(K, C*R*S).
    stacked = jnp.stack(cols, axis=2)
    return stacked.reshape(n, c * r * s, ho * wo)
