"""WINOGRAD_NONFUSED convolution — F(2x2, 3x3) — as a Pallas kernel.

cuDNN's *nonfused* Winograd runs the three stages as separate kernels with
the transformed tensors staged in workspace memory (hence the 691 MB entry
in the paper's Table 2): input transform, 16 independent batched GEMMs over
the frequency positions, output transform. We mirror that structure:
transforms at the jnp level (cheap, bandwidth-bound), the GEMM stage as the
shared Pallas batched-matmul kernel (compute-bound, MXU-shaped).

Constraints match cuDNN: 3x3 filter, stride 1 (the paper's Table 2 notes
DIRECT/WINOGRAD unsupported for some inputs; we raise for unsupported
configurations just like cuDNN returns CUDNN_STATUS_NOT_SUPPORTED).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import ref
from .common import bmm

# F(2x2, 3x3) transform matrices (Lavin & Gray, 2016).
_BT = np.array(
    [
        [1, 0, -1, 0],
        [0, 1, 1, 0],
        [0, -1, 1, 0],
        [0, 1, 0, -1],
    ],
    dtype=np.float32,
)
_G = np.array(
    [
        [1, 0, 0],
        [0.5, 0.5, 0.5],
        [0.5, -0.5, 0.5],
        [0, 0, 1],
    ],
    dtype=np.float32,
)
_AT = np.array(
    [
        [1, 1, 1, 0],
        [0, 1, -1, -1],
    ],
    dtype=np.float32,
)


class NotSupported(ValueError):
    """Mirror of CUDNN_STATUS_NOT_SUPPORTED for this algorithm."""


def _check(w_shape, stride):
    k, c, r, s = w_shape
    if (r, s) != (3, 3) or stride != (1, 1):
        raise NotSupported(
            f"WINOGRAD_NONFUSED supports 3x3/stride1 only, got {r}x{s}/{stride}"
        )


@functools.partial(jax.jit, static_argnames=("stride", "padding"))
def conv2d_winograd(x, w, stride=(1, 1), padding=(0, 0)):
    """Winograd F(2x2, 3x3) convolution (stride 1, 3x3 filters only)."""
    _check(w.shape, stride)
    n, c, h, wd = x.shape
    k = w.shape[0]
    ho, wo = ref.out_dims(h, wd, 3, 3, stride, padding)
    # Pad: user padding, then round the output up to 2x2 tiles.
    th, tw = (ho + 1) // 2, (wo + 1) // 2
    need_h = 2 * th + 2  # input extent consumed by th tiles of F(2,3)
    need_w = 2 * tw + 2
    xp = jnp.pad(
        x,
        (
            (0, 0),
            (0, 0),
            (padding[0], need_h - h - padding[0]),
            (padding[1], need_w - wd - padding[1]),
        ),
    )
    bt = jnp.asarray(_BT)
    g = jnp.asarray(_G)
    at = jnp.asarray(_AT)

    # --- input transform: 4x4 tiles, stride 2 -> U (16, C, N*T) ---
    tiles = []
    for i in range(th):
        for j in range(tw):
            tiles.append(xp[:, :, 2 * i : 2 * i + 4, 2 * j : 2 * j + 4])
    d = jnp.stack(tiles, axis=2)  # (N, C, T, 4, 4)
    # U = BT @ d @ B per tile: (4,4) x (N,C,T,4,4) x (4,4)
    u = jnp.einsum("ab,nqtbd->nqtad", bt, d)
    u = jnp.einsum("nqtad,db->nqtab", u, bt.T)
    p = n * th * tw
    u = u.transpose(3, 4, 1, 0, 2).reshape(16, c, p)  # (16, C, P)

    # --- filter transform: V (16, K, C) ---
    v = jnp.einsum("ab,kqbd->kqad", g, w)
    v = jnp.einsum("kqad,db->kqab", v, g.T)
    v = v.transpose(2, 3, 0, 1).reshape(16, k, c)

    # --- 16 independent GEMMs (the Pallas stage) : M (16, K, P) ---
    m = bmm(v, u)

    # --- output transform: Y = AT @ M @ A ---
    m = m.reshape(4, 4, k, n, th * tw).transpose(3, 2, 4, 0, 1)  # (N,K,T,4,4)
    y = jnp.einsum("ab,nktbd->nktad", at, m)
    y = jnp.einsum("nktad,db->nktab", y, at.T)  # (N, K, T, 2, 2)
    y = y.reshape(n, k, th, tw, 2, 2).transpose(0, 1, 2, 4, 3, 5)
    y = y.reshape(n, k, 2 * th, 2 * tw)
    return y[:, :, :ho, :wo].astype(x.dtype)


def workspace_bytes(x_shape, w_shape, stride=(1, 1), padding=(0, 0),
                    bytes_per_el: int = 4) -> int:
    """Workspace for the nonfused pipeline: U + V + M staged in memory."""
    _check(w_shape, stride)
    n, c, h, wd = x_shape
    k = w_shape[0]
    ho, wo = ref.out_dims(h, wd, 3, 3, stride, padding)
    th, tw = (ho + 1) // 2, (wo + 1) // 2
    p = n * th * tw
    return (16 * c * p + 16 * k * c + 16 * k * p) * bytes_per_el
