"""IMPLICIT_GEMM and IMPLICIT_PRECOMP_GEMM convolutions as Pallas kernels.

cuDNN's implicit-GEMM family performs the same virtual GEMM as im2col-GEMM
but never materializes the column matrix in device memory:

- ``IMPLICIT_GEMM``: gathers input patches on the fly inside the kernel —
  zero workspace (well, cuDNN reports ~48 KB of bookkeeping; see
  convlib/implicit_gemm.rs), register-hungry (the paper's Table 1 shows
  ``implicit_convolve_sgemm`` at 92-100 % register utilization).
- ``IMPLICIT_PRECOMP_GEMM``: additionally precomputes the gather index
  tables once (small workspace) so the inner loop is a pure gather+MAC.

On TPU the "gather into registers" becomes: stage the padded input block in
VMEM via BlockSpec, build the (C*R*S, tile) patch panel with static shifted
slices (unrolled at trace time — this is the precomputed-offset analogue),
and feed the MXU with a (K, C*R*S) x (C*R*S, tile) product.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from . import ref


def _implicit_kernel(x_ref, w_ref, o_ref, *, r, s, stride, ho, wo):
    # x_ref: (1, C, Hp, Wp); w_ref: (K, C*R*S); o_ref: (1, K, Ho*Wo)
    x = x_ref[0]
    sh, sw = stride
    panels = []
    # Unrolled patch gather: the implicit im2col. Lives only in VMEM.
    for dr in range(r):
        for ds in range(s):
            win = x[:, dr : dr + (ho - 1) * sh + 1 : sh,
                       ds : ds + (wo - 1) * sw + 1 : sw]
            panels.append(win.reshape(x.shape[0], ho * wo))
    # (C, R*S, Ho*Wo) -> (C*R*S, Ho*Wo), C-major to match w.reshape(K, CRS).
    panel = jnp.stack(panels, axis=1).reshape(-1, ho * wo)
    o_ref[0] = jnp.dot(
        w_ref[...], panel, preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("stride", "padding"))
def conv2d_implicit_gemm(x, w, stride=(1, 1), padding=(0, 0)):
    """Implicit GEMM: virtual im2col gathered in VMEM, zero device workspace."""
    n, c, h, wd = x.shape
    k, _, r, s = w.shape
    ho, wo = ref.out_dims(h, wd, r, s, stride, padding)
    xp = jnp.pad(
        x, ((0, 0), (0, 0), (padding[0], padding[0]), (padding[1], padding[1]))
    )
    hp, wp = xp.shape[2], xp.shape[3]
    wmat = w.reshape(k, c * r * s)
    kern = functools.partial(
        _implicit_kernel, r=r, s=s, stride=stride, ho=ho, wo=wo
    )
    out = pl.pallas_call(
        kern,
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1, c, hp, wp), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((k, c * r * s), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, k, ho * wo), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, k, ho * wo), x.dtype),
        interpret=True,
    )(xp, wmat)
    return out.reshape(n, k, ho, wo)


def _precomp_indices(c, hp, wp, r, s, stride, ho, wo):
    """The PRECOMP part: flat gather indices computed once at build time.

    Returns an int32 array of shape (C*R*S, Ho*Wo) indexing into the
    flattened (C*Hp*Wp) padded image. This is the workspace cuDNN's
    IMPLICIT_PRECOMP_GEMM allocates.
    """
    sh, sw = stride
    idx = np.empty((c * r * s, ho * wo), dtype=np.int32)
    row = 0
    for ch in range(c):
        for dr in range(r):
            for ds in range(s):
                base = ch * hp * wp
                ii, jj = np.meshgrid(
                    np.arange(ho) * sh + dr, np.arange(wo) * sw + ds,
                    indexing="ij",
                )
                idx[row] = (base + ii * wp + jj).reshape(-1)
                row += 1
    return idx


def _precomp_kernel(x_ref, w_ref, idx_ref, o_ref):
    # x_ref: (1, C*Hp*Wp) flat padded image; idx_ref: (CRS, Ho*Wo) int32;
    # w_ref: (K, CRS); o_ref: (1, K, Ho*Wo)
    flat = x_ref[0]
    panel = flat[idx_ref[...]]  # pure gather: the precomputed-offset loop
    o_ref[0] = jnp.dot(
        w_ref[...], panel, preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("stride", "padding"))
def conv2d_precomp_gemm(x, w, stride=(1, 1), padding=(0, 0)):
    """Implicit GEMM with precomputed gather-index workspace."""
    n, c, h, wd = x.shape
    k, _, r, s = w.shape
    ho, wo = ref.out_dims(h, wd, r, s, stride, padding)
    xp = jnp.pad(
        x, ((0, 0), (0, 0), (padding[0], padding[0]), (padding[1], padding[1]))
    )
    hp, wp = xp.shape[2], xp.shape[3]
    idx = jnp.asarray(_precomp_indices(c, hp, wp, r, s, stride, ho, wo))
    flat = xp.reshape(n, c * hp * wp)
    wmat = w.reshape(k, c * r * s)
    out = pl.pallas_call(
        _precomp_kernel,
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1, c * hp * wp), lambda i: (i, 0)),
            pl.BlockSpec((k, c * r * s), lambda i: (0, 0)),
            pl.BlockSpec((c * r * s, ho * wo), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, k, ho * wo), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, k, ho * wo), x.dtype),
        interpret=True,
    )(flat, wmat, idx)
    return out.reshape(n, k, ho, wo)


def precomp_workspace_bytes(x_shape, w_shape, stride=(1, 1), padding=(0, 0)):
    """Index-table workspace for IMPLICIT_PRECOMP_GEMM (int32 entries)."""
    n, c, h, wd = x_shape
    k, _, r, s = w_shape
    ho, wo = ref.out_dims(h, wd, r, s, stride, padding)
    return c * r * s * ho * wo * 4
