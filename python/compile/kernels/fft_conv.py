"""FFT and FFT_TILING convolutions (cuDNN FFT / FFT_TILING algorithms).

Frequency-domain cross-correlation: Y_f[n,k] = sum_c X_f[n,c] * conj(W_f[k,c]),
then inverse transform. The frequency tensors are the workspace — for FFT
over the full image this is the 2.2 GB entry in the paper's Table 2; tiling
the image into 32x32 chunks (cuDNN's ``fft2d_c2r_32x32`` kernel, Table 1)
cuts the resident workspace roughly in half at the cost of redundant halo
transforms, exactly the FFT vs FFT_TILING trade the paper tabulates.

These stay at the jnp/XLA level rather than hand-written Pallas: FFT has no
MXU-shaped inner loop to win on TPU (DESIGN.md §Hardware-Adaptation) and XLA
fuses the pointwise frequency product already. Constraint (as in cuDNN):
stride 1 only; FFT_TILING additionally requires R,S <= tile.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref


class NotSupported(ValueError):
    """Mirror of CUDNN_STATUS_NOT_SUPPORTED for the FFT family."""


_TILE = 32  # cuDNN fft2d_*_32x32 tile edge


def _freq_correlate(xp, w, lh, lw):
    """Circular cross-correlation via rFFT over (lh, lw) signals."""
    xf = jnp.fft.rfft2(xp, s=(lh, lw))              # (N, C, lh, lwf)
    wf = jnp.fft.rfft2(w, s=(lh, lw))               # (K, C, lh, lwf)
    yf = jnp.einsum("nchw,kchw->nkhw", xf, jnp.conj(wf))
    return jnp.fft.irfft2(yf, s=(lh, lw))           # (N, K, lh, lw)


@functools.partial(jax.jit, static_argnames=("stride", "padding"))
def conv2d_fft(x, w, stride=(1, 1), padding=(0, 0)):
    """Full-image FFT convolution. Stride 1 only."""
    if stride != (1, 1):
        raise NotSupported(f"FFT requires stride 1, got {stride}")
    n, c, h, wd = x.shape
    k, _, r, s = w.shape
    ho, wo = ref.out_dims(h, wd, r, s, stride, padding)
    xp = jnp.pad(
        x, ((0, 0), (0, 0), (padding[0], padding[0]), (padding[1], padding[1]))
    )
    hp, wp = xp.shape[2], xp.shape[3]
    y = _freq_correlate(xp, w, hp, wp)
    return y[:, :, :ho, :wo].astype(x.dtype)


@functools.partial(jax.jit, static_argnames=("stride", "padding", "tile"))
def conv2d_fft_tiling(x, w, stride=(1, 1), padding=(0, 0), tile: int = _TILE):
    """Tiled FFT convolution: independent (tile+halo) FFTs per output tile.

    Matches cuDNN FFT_TILING: each 32x32 output tile is produced by a
    transform over the (tile + R - 1) input patch; the per-tile frequency
    workspace is reused across tiles.
    """
    if stride != (1, 1):
        raise NotSupported(f"FFT_TILING requires stride 1, got {stride}")
    n, c, h, wd = x.shape
    k, _, r, s = w.shape
    if r > tile or s > tile:
        raise NotSupported(f"filter {r}x{s} exceeds FFT tile {tile}")
    ho, wo = ref.out_dims(h, wd, r, s, stride, padding)
    xp = jnp.pad(
        x, ((0, 0), (0, 0), (padding[0], padding[0]), (padding[1], padding[1]))
    )
    hp, wp = xp.shape[2], xp.shape[3]
    lh, lw = tile + r - 1, tile + s - 1
    # Pad so every tile's halo read is in bounds.
    ty, tx = -(-ho // tile), -(-wo // tile)
    xp = jnp.pad(
        xp,
        (
            (0, 0),
            (0, 0),
            (0, max(0, (ty - 1) * tile + lh - hp)),
            (0, max(0, (tx - 1) * tile + lw - wp)),
        ),
    )
    rows = []
    for i in range(ty):
        cols = []
        for j in range(tx):
            patch = xp[:, :, i * tile : i * tile + lh, j * tile : j * tile + lw]
            y = _freq_correlate(patch, w, lh, lw)[:, :, :tile, :tile]
            cols.append(y)
        rows.append(jnp.concatenate(cols, axis=3))
    full = jnp.concatenate(rows, axis=2)
    return full[:, :, :ho, :wo].astype(x.dtype)


def _rfft_ws(n, c, k, lh, lw, batch_tiles=1, bytes_per_el=8):
    lwf = lw // 2 + 1
    return (n * c + k * c + n * k) * lh * lwf * bytes_per_el * batch_tiles


def workspace_bytes_fft(x_shape, w_shape, stride=(1, 1), padding=(0, 0)):
    """Frequency-domain workspace (complex64) for full-image FFT."""
    n, c, h, wd = x_shape
    k, _, r, s = w_shape
    hp, wp = h + 2 * padding[0], wd + 2 * padding[1]
    return _rfft_ws(n, c, k, hp, wp)


def workspace_bytes_fft_tiling(x_shape, w_shape, stride=(1, 1),
                               padding=(0, 0), tile: int = _TILE):
    """Per-batch-of-tiles frequency workspace for FFT_TILING.

    cuDNN processes tiles in batches, keeping roughly half the full-FFT
    frequency state resident (Table 2: 1.1 GB vs 2.2 GB).
    """
    n, c, h, wd = x_shape
    k, _, r, s = w_shape
    ho, wo = ref.out_dims(h, wd, r, s, stride, padding)
    ty, tx = -(-ho // tile), -(-wo // tile)
    lh, lw = tile + r - 1, tile + s - 1
    # filter transform is shared; input/output frequency state for half the
    # tile grid is resident at once.
    resident = max(1, (ty * tx) // 2)
    lwf = lw // 2 + 1
    return ((n * c + n * k) * lh * lwf * resident + k * c * lh * lwf) * 8
