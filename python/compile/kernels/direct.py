"""DIRECT convolution as a Pallas kernel (cuDNN CUDNN_CONVOLUTION_FWD_ALGO_DIRECT).

Zero workspace: each grid program owns one (image, output-channel-tile) pair,
keeps the whole padded input image for that batch element in VMEM, and
accumulates the R*S shifted-window products in registers. This is the TPU
re-think of a CUDA direct kernel: the threadblock's shared-memory input
staging becomes the BlockSpec HBM->VMEM copy, and the per-thread accumulator
becomes a vector-register tile (DESIGN.md §Hardware-Adaptation).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _direct_kernel(x_ref, w_ref, o_ref, *, r, s, stride, ho, wo):
    # x_ref: (1, C, Hp, Wp) padded input for one image
    # w_ref: (bk, C, R, S)  filter tile
    # o_ref: (1, bk, Ho, Wo)
    x = x_ref[0]          # (C, Hp, Wp)
    w = w_ref[...]        # (bk, C, R, S)
    sh, sw = stride
    acc = jnp.zeros(o_ref.shape[1:], dtype=jnp.float32)  # (bk, Ho, Wo)
    for dr in range(r):
        for ds in range(s):
            # (C, Ho, Wo) strided window
            win = x[:, dr : dr + (ho - 1) * sh + 1 : sh,
                       ds : ds + (wo - 1) * sw + 1 : sw]
            # (bk, C) x (C, Ho, Wo) -> (bk, Ho, Wo)
            acc = acc + jnp.einsum(
                "kc,chw->khw", w[:, :, dr, ds], win,
                preferred_element_type=jnp.float32,
            )
    o_ref[0] = acc.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("stride", "padding", "bk"))
def conv2d_direct(x, w, stride=(1, 1), padding=(0, 0), bk: int = 32):
    """Direct convolution. Supports any stride/padding; workspace = 0."""
    n, c, h, wd = x.shape
    k, _, r, s = w.shape
    ho, wo = ref.out_dims(h, wd, r, s, stride, padding)
    xp = jnp.pad(
        x, ((0, 0), (0, 0), (padding[0], padding[0]), (padding[1], padding[1]))
    )
    hp, wp = xp.shape[2], xp.shape[3]
    bk = min(bk, k)
    # Pad K to a multiple of the channel tile.
    krem = (-k) % bk
    wpad = jnp.pad(w, ((0, krem), (0, 0), (0, 0), (0, 0)))
    kp = k + krem
    kern = functools.partial(
        _direct_kernel, r=r, s=s, stride=stride, ho=ho, wo=wo
    )
    out = pl.pallas_call(
        kern,
        grid=(n, kp // bk),
        in_specs=[
            pl.BlockSpec((1, c, hp, wp), lambda i, j: (i, 0, 0, 0)),
            pl.BlockSpec((bk, c, r, s), lambda i, j: (j, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bk, ho, wo), lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, kp, ho, wo), x.dtype),
        interpret=True,
    )(xp, wpad)
    return out[:, :k]
