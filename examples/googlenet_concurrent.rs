//! Scenario example: schedule one full GoogleNet training-iteration's
//! forward graph under every policy/partition regime, print the comparison,
//! and dump a chrome trace of the most interesting co-execution.
//!
//! ```bash
//! cargo run --release --offline --example googlenet_concurrent -- [batch]
//! ```

use parconv::convlib::{kernel_desc, Algorithm, ConvParams};
use parconv::coordinator::{
    PriorityPolicy, ScheduleConfig, SelectionPolicy,
};
use parconv::gpusim::{DeviceSpec, Engine, PartitionMode};
use parconv::graph::Network;
use parconv::plan::Session;
use parconv::profiler::chrome_trace_json;
use parconv::util::{fmt_bytes, fmt_us, Table};

fn main() -> anyhow::Result<()> {
    let batch: usize = std::env::args()
        .nth(1)
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(32);
    let dev = DeviceSpec::k40();
    let dag = Network::GoogleNet.build(batch);
    println!(
        "GoogleNet, batch {batch}: {} ops, {} convs, {} independent conv pairs\n",
        dag.len(),
        dag.conv_ids().len(),
        dag.independent_conv_pairs().len()
    );

    let mut table = Table::new(vec![
        "Policy",
        "Partition",
        "Streams",
        "Makespan",
        "vs baseline",
        "Conv overlap",
        "Peak workspace",
    ]);
    let mut baseline = None;
    for (policy, partition, streams) in [
        (SelectionPolicy::FastestOnly, PartitionMode::Serial, 1),
        (SelectionPolicy::FastestOnly, PartitionMode::StreamsOnly, 4),
        (SelectionPolicy::MemoryMin, PartitionMode::Serial, 1),
        (SelectionPolicy::Balanced, PartitionMode::Serial, 1),
        (SelectionPolicy::ProfileGuided, PartitionMode::InterSm, 2),
        (SelectionPolicy::ProfileGuided, PartitionMode::IntraSm, 2),
        (SelectionPolicy::ProfileGuided, PartitionMode::IntraSm, 4),
    ] {
        let r = Session::new(
            dev.clone(),
            ScheduleConfig {
                policy,
                partition,
                streams,
                workspace_limit: 4 * 1024 * 1024 * 1024,
                priority: PriorityPolicy::CriticalPath,
            },
        )
        .run(&dag);
        let base = *baseline.get_or_insert(r.makespan_us);
        table.row(vec![
            policy.name().to_string(),
            partition.name().to_string(),
            streams.to_string(),
            fmt_us(r.makespan_us),
            format!("{:.2}x", base / r.makespan_us),
            fmt_us(r.conv_overlap_us),
            fmt_bytes(r.peak_workspace),
        ]);
    }
    println!("{}", table.render());

    // Trace the Table-1 pair co-executing under intra-SM quotas.
    let p3 = ConvParams::incep3a_3x3(batch);
    let p5 = ConvParams::incep3a_5x5(batch);
    let mut e = Engine::new(dev.clone(), PartitionMode::IntraSm);
    e.launch(
        kernel_desc(Algorithm::ImplicitPrecompGemm, &p3, &dev).unwrap(),
        0,
    );
    e.launch(kernel_desc(Algorithm::FftTiling, &p5, &dev).unwrap(), 1);
    let sim = e.run();
    std::fs::write("googlenet_pair_trace.json", chrome_trace_json(&sim))?;
    println!(
        "wrote googlenet_pair_trace.json (open in chrome://tracing or Perfetto)"
    );
    Ok(())
}
