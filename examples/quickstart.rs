//! Quickstart: the library in five minutes.
//!
//! ```bash
//! cargo run --release --offline --example quickstart
//! ```
//!
//! Walks the paper's argument end to end: (1) non-linear networks expose
//! independent convolutions; (2) cuDNN-style algorithm picks exhaust SM
//! resources, so streams alone serialize; (3) profile-guided algorithm
//! selection + intra-SM partitioning makes concurrency real.

use parconv::convlib::{kernel_desc, Algorithm, ConvParams};
use parconv::coordinator::{
    discover_pairs, ScheduleConfig, SelectionPolicy,
};
use parconv::gpusim::{DeviceSpec, Engine, PartitionMode};
use parconv::graph::Network;
use parconv::plan::Session;
use parconv::profiler::{table1_report, table1_row};
use parconv::util::fmt_us;

fn main() {
    let dev = DeviceSpec::k40();
    println!("device: {} ({} SMs)\n", dev.name, dev.num_sms);

    // 1. Structure: AlexNet is a chain, GoogleNet forks four ways.
    let alex = Network::AlexNet.build(32).stats();
    let goog = Network::GoogleNet.build(32).stats();
    println!(
        "AlexNet:   {} convs, {} independent conv pairs (linear: {})",
        alex.convs, alex.independent_conv_pairs, alex.is_linear()
    );
    println!(
        "GoogleNet: {} convs, {} independent conv pairs (linear: {})\n",
        goog.convs, goog.independent_conv_pairs, goog.is_linear()
    );

    // 2. Profile the two independent inception-3a convolutions (Table 1).
    let p3 = ConvParams::incep3a_3x3(32);
    let p5 = ConvParams::incep3a_5x5(32);
    let rows: Vec<_> = [
        ("3x3", Algorithm::ImplicitPrecompGemm, &p3),
        ("3x3", Algorithm::FftTiling, &p3),
        ("5x5", Algorithm::ImplicitPrecompGemm, &p5),
        ("5x5", Algorithm::FftTiling, &p5),
    ]
    .iter()
    .filter_map(|(l, a, p)| table1_row(l, *a, p, &dev))
    .collect();
    println!("{}", table1_report(&rows));

    // 3. Streams alone don't help; complementary algos + intra-SM do.
    let scenario = |aa, ab, mode| {
        let mut e = Engine::new(dev.clone(), mode);
        e.launch(kernel_desc(aa, &p3, &dev).unwrap(), 0);
        e.launch(kernel_desc(ab, &p3, &dev).unwrap(), 1);
        let r = e.run();
        (r.makespan_us, r.speedup_vs_serial())
    };
    let (t_tf, s_tf) = scenario(
        Algorithm::ImplicitPrecompGemm,
        Algorithm::ImplicitPrecompGemm,
        PartitionMode::StreamsOnly,
    );
    let (t_cp, s_cp) = scenario(
        Algorithm::ImplicitPrecompGemm,
        Algorithm::FftTiling,
        PartitionMode::IntraSm,
    );
    println!(
        "two streams, TF picks:            {} ({s_tf:.2}x vs serial)",
        fmt_us(t_tf)
    );
    println!(
        "intra-SM, complementary algos:    {} ({s_cp:.2}x vs serial)\n",
        fmt_us(t_cp)
    );

    // 4. How many such opportunities exist in GoogleNet?
    let dag = Network::GoogleNet.build(32);
    let findings =
        discover_pairs(&dag, &dev, 4 * 1024 * 1024 * 1024, 1.05);
    println!(
        "complementary pairs in GoogleNet:  {} (paper: \"27 similar cases\")\n",
        findings.len()
    );

    // 5. Whole-network iteration under both regimes. A Session plans
    //    once (selection, grouping, quotas) and replays the cached plan
    //    on every subsequent run of the same network/batch.
    let serial = Session::new(
        dev.clone(),
        ScheduleConfig {
            policy: SelectionPolicy::FastestOnly,
            partition: PartitionMode::Serial,
            streams: 1,
            ..Default::default()
        },
    )
    .run(&dag);
    let guided = Session::new(
        dev.clone(),
        ScheduleConfig {
            policy: SelectionPolicy::ProfileGuided,
            partition: PartitionMode::IntraSm,
            streams: 2,
            ..Default::default()
        },
    );
    let conc = guided.run(&dag);
    println!(
        "GoogleNet iteration, serial fastest-only:      {}",
        fmt_us(serial.makespan_us)
    );
    println!(
        "GoogleNet iteration, profile-guided intra-SM:  {}  ({:.2}x)",
        fmt_us(conc.makespan_us),
        serial.makespan_us / conc.makespan_us
    );

    // 6. The serving loop: repeated runs hit the plan cache and skip
    //    selection entirely (the paper's offline-profiles point).
    for _ in 0..3 {
        guided.run(&dag);
    }
    let stats = guided.stats();
    println!(
        "\nplan cache after 4 runs: {} plan built, {} hits \
         ({:.0}% hit rate)",
        stats.plans_built,
        stats.cache_hits,
        stats.hit_rate() * 100.0
    );
}
