//! Scenario example: reproduce the paper's §2.1 discovery claim — scan
//! every network for complementary convolution pairs, across workspace
//! budgets, and print the census.
//!
//! ```bash
//! cargo run --release --offline --example discover_pairs -- [batch]
//! ```

use parconv::coordinator::discover_pairs;
use parconv::gpusim::DeviceSpec;
use parconv::graph::Network;
use parconv::util::{fmt_bytes, Table};

fn main() -> anyhow::Result<()> {
    let batch: usize = std::env::args()
        .nth(1)
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(32);
    let dev = DeviceSpec::k40();
    println!(
        "complementary-pair census at batch {batch} on {} (min speedup 1.05x)\n",
        dev.name
    );
    let budgets: [u64; 3] = [
        512 * 1024 * 1024,
        2 * 1024 * 1024 * 1024,
        4 * 1024 * 1024 * 1024,
    ];
    let mut t = Table::new(vec![
        "Network",
        "Indep. conv pairs",
        "Budget 512MB",
        "Budget 2GB",
        "Budget 4GB",
        "Best speedup",
    ]);
    for net in Network::ALL {
        let dag = net.build(batch);
        let total = dag.independent_conv_pairs().len();
        let mut counts = Vec::new();
        let mut best = 0.0f64;
        for b in budgets {
            let f = discover_pairs(&dag, &dev, b, 1.05);
            if let Some(top) = f.first() {
                best = best.max(top.speedup());
            }
            counts.push(f.len());
        }
        t.row(vec![
            net.name().to_string(),
            total.to_string(),
            counts[0].to_string(),
            counts[1].to_string(),
            counts[2].to_string(),
            if best > 0.0 {
                format!("{best:.2}x")
            } else {
                "-".into()
            },
        ]);
    }
    println!("{}", t.render());
    println!(
        "(budgets are the workspace headroom left beside tensors; {} total \
         device memory)",
        fmt_bytes(DeviceSpec::k40().global_mem)
    );
    println!("\npaper claim: \"We discover 27 similar cases in this network \
             [GoogleNet] and more instances in other popular non-linear CNNs \
             such as ResNet.\"");
    Ok(())
}
