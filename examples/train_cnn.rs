//! End-to-end driver (E8): train the mini-GoogleNet for a few hundred steps
//! through the full three-layer stack —
//!
//!   Rust loop  ->  PJRT CPU executable  ->  XLA HLO lowered from JAX,
//!   containing the Pallas convolution kernels of the selected algorithms
//!
//! — and log the loss curve. Requires `make artifacts`.
//!
//! ```bash
//! cargo run --release --offline --example train_cnn -- [steps]
//! ```

use std::path::Path;

use parconv::trainer::Trainer;

fn main() -> anyhow::Result<()> {
    let steps: usize = std::env::args()
        .nth(1)
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(300);
    let dir = Path::new("artifacts");
    anyhow::ensure!(
        dir.join("manifest.txt").exists(),
        "artifacts not built — run `make artifacts` first"
    );

    println!("loading AOT artifacts from {}", dir.display());
    let mut trainer = Trainer::new(dir)?;
    println!(
        "mini-GoogleNet: {} parameter tensors, {} data batches\n",
        trainer.num_params(),
        trainer.num_batches()
    );

    let t0 = std::time::Instant::now();
    let log_every = (steps / 25).max(1);
    let logs = trainer.train(steps, log_every, |l| {
        let bar_len = ((l.loss / 2.5).min(1.0) * 40.0) as usize;
        println!(
            "step {:4}  loss {:7.4}  |{}{}|",
            l.step,
            l.loss,
            "#".repeat(bar_len),
            " ".repeat(40 - bar_len)
        );
    })?;
    let wall = t0.elapsed().as_secs_f64();

    let first = logs.first().unwrap().loss;
    let min = logs.iter().map(|l| l.loss).fold(f32::INFINITY, f32::min);
    let last = logs.last().unwrap().loss;
    let mean_ms: f64 =
        logs.iter().map(|l| l.wall_ms).sum::<f64>() / logs.len() as f64;
    println!("\n=== training summary ===");
    println!("steps:        {steps}");
    println!("loss:         {first:.4} -> {last:.4} (min {min:.4})");
    println!("wall:         {wall:.1} s ({mean_ms:.1} ms/step)");
    anyhow::ensure!(last < first, "loss did not descend");

    std::fs::write(
        "loss_curve.csv",
        logs.iter()
            .map(|l| format!("{},{}\n", l.step, l.loss))
            .collect::<String>(),
    )?;
    println!("wrote loss_curve.csv");
    Ok(())
}
